//! SORT's 7-state constant-velocity Kalman filter.
//!
//! State `x = [u, v, s, r, du, dv, ds]`; measurement `z = [u, v, s, r]`.
//! Constants (`F`, `H`, `Q`, `R`, `P0`) are exactly abewley/sort's
//! `KalmanBoxTracker` setup — pinned against `artifacts/parity.json`
//! (exported from the JAX oracle) by the tests in
//! `rust/tests/integration_parity.rs`.
//!
//! The update uses the Joseph-form covariance
//! `P' = (I-KH) P (I-KH)' + K R K'` — what filterpy (and hence the
//! original Python SORT) computes — rather than the cheaper
//! `(I-KH) P`: it is unconditionally symmetric-positive-semidefinite,
//! which matters over thousand-frame sequences. The cost difference is
//! itself an ablation (bench `ablations`, E9).

use crate::linalg::{chol_inverse, Mat, Mat4, Mat4x7, Mat7, Mat7x4, Vec4, Vec7, DIM_X};

/// The five constant matrices of SORT's filter.
#[derive(Debug, Clone)]
pub struct SortConstants {
    /// State transition (7×7): identity + velocity coupling, dt = 1.
    pub f: Mat7,
    /// Measurement model (4×7): observe the first four state entries.
    pub h: Mat4x7,
    /// Process noise (7×7 diagonal).
    pub q: Mat7,
    /// Measurement noise (4×4 diagonal).
    pub r: Mat4,
    /// Initial covariance (7×7 diagonal): huge velocity uncertainty.
    pub p0: Mat7,
}

impl SortConstants {
    /// The exact constants of the original implementation.
    pub fn sort_defaults() -> Self {
        let mut f = Mat7::eye();
        f[(0, 4)] = 1.0;
        f[(1, 5)] = 1.0;
        f[(2, 6)] = 1.0;

        let mut h = Mat4x7::zeros();
        for i in 0..4 {
            h[(i, i)] = 1.0;
        }

        // R = eye(4); R[2:,2:] *= 10
        let r = Mat4::diag(&[1.0, 1.0, 10.0, 10.0]);

        // P = eye(7); P[4:,4:] *= 1000; P *= 10
        let p0 = Mat7::diag(&[10.0, 10.0, 10.0, 10.0, 10000.0, 10000.0, 10000.0]);

        // Q = eye(7); Q[-1,-1] *= 0.01; Q[4:,4:] *= 0.01
        let q = Mat7::diag(&[1.0, 1.0, 1.0, 1.0, 0.01, 0.01, 0.0001]);

        SortConstants { f, h, q, r, p0 }
    }
}

/// Covariance-update strategy (ablation E9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CovarianceForm {
    /// `(I-KH) P (I-KH)' + K R K'` — filterpy / original SORT.
    #[default]
    Joseph,
    /// `(I-KH) P` — cheaper, numerically fragile.
    Simple,
}

/// Mutable filter state of one tracker: mean + covariance.
#[derive(Debug, Clone, Copy)]
pub struct KalmanState {
    /// State mean `[u, v, s, r, du, dv, ds]`.
    pub x: Vec7,
    /// State covariance.
    pub p: Mat7,
}

impl KalmanState {
    /// Fresh tracker seeded from a measurement (velocities zero,
    /// covariance `P0`).
    pub fn from_measurement(z: &Vec4, consts: &SortConstants) -> Self {
        KalmanState {
            x: [z[0], z[1], z[2], z[3], 0.0, 0.0, 0.0],
            p: consts.p0,
        }
    }

    /// Time update: `x <- F x`, `P <- F P F' + Q`, preceded by SORT's
    /// negative-area guard (`if x[6] + x[2] <= 0 { x[6] = 0 }`).
    ///
    /// Structure-aware (§Perf): SORT's `F = I + E` where `E` has exactly
    /// three ones (velocity coupling), so `F P F' = P + E P + P E' +
    /// E P E'` reduces to row/column shifts — ~100 adds instead of two
    /// dense 7×7 GEMMs (~1.4 kflop). Numerically identical to
    /// [`Self::predict_dense`] (unit-tested to 1e-12).
    pub fn predict(&mut self, consts: &SortConstants) {
        if self.x[6] + self.x[2] <= 0.0 {
            self.x[6] = 0.0;
        }
        // x' = F x : positions += velocities
        self.x[0] += self.x[4];
        self.x[1] += self.x[5];
        self.x[2] += self.x[6];

        // A = F P  (A[r] = P[r] + P[r+4] for r < 3)
        let p = &mut self.p;
        crate::linalg::counters::record(
            crate::linalg::counters::Kernel::Gemm,
            2 * (3 * 7 + 7 * 3 + 3 * 3) as u64 + 49 + 3,
            (2 * 49 + 49) * 8,
        );
        let mut a = *p;
        for r in 0..3 {
            for c in 0..7 {
                a[(r, c)] += p[(r + 4, c)];
            }
        }
        // P' = A F' + Q  ((A F')[.,c] = A[.,c] + A[.,c+4] for c < 3)
        for r in 0..7 {
            for c in 0..3 {
                a[(r, c)] += a[(r, c + 4)];
            }
        }
        *p = a.add(&consts.q);
    }

    /// Dense-GEMM time update — the paper's library-kernel formulation
    /// (kept for the Table II/IV accounting runs and the E9.4 ablation).
    pub fn predict_dense(&mut self, consts: &SortConstants) {
        if self.x[6] + self.x[2] <= 0.0 {
            self.x[6] = 0.0;
        }
        self.x = consts.f.matvec(&self.x);
        let fp = consts.f.matmul(&self.p);
        self.p = fp.matmul_nt(&consts.f).add(&consts.q);
    }

    /// Measurement update with measurement `z = [u, v, s, r]`.
    ///
    /// Structure-aware (§Perf): SORT's `H = [I₄ | 0]` means `H x` is a
    /// slice, `P H'` is the first four columns of `P`, `S` is the top-
    /// left 4×4 block plus diagonal `R`, and `(I - K H)` only perturbs
    /// the first four columns — the Joseph chain collapses from five
    /// dense GEMMs to three 7×7×4 half-contractions. Numerically
    /// equivalent to [`Self::update_dense`] (unit-tested to 1e-10).
    ///
    /// Returns `false` (leaving the state untouched) if the innovation
    /// covariance is not SPD — a corrupt tracker the caller should cull.
    pub fn update(&mut self, z: &Vec4, consts: &SortConstants, form: CovarianceForm) -> bool {
        let p = &self.p;
        // y = z - H x = z - x[0..4]
        let y = [z[0] - self.x[0], z[1] - self.x[1], z[2] - self.x[2], z[3] - self.x[3]];

        // S = (H P H') + R = P[0..4][0..4] + diag(R)
        let mut s = Mat4::zeros();
        for r in 0..4 {
            for c in 0..4 {
                s[(r, c)] = p[(r, c)];
            }
            s[(r, r)] += consts.r[(r, r)];
        }
        // K = P H' S^-1 = P[:,0..4] * S^-1  (7x4). A direct triangular-
        // solve formulation was tried and reverted (§Perf iteration 3):
        // at 4x4 the explicit inverse + 224-madd GEMM wins.
        let s_inv = match chol_inverse(&s) {
            Some(inv) => inv,
            None => return false,
        };
        crate::linalg::counters::record(
            crate::linalg::counters::Kernel::Gemm,
            2 * (7 * 4 * 4) as u64,
            (7 * 4 + 16 + 7 * 4) * 8,
        );
        let mut k = Mat7x4::zeros();
        for r in 0..7 {
            for c in 0..4 {
                let mut acc = 0.0;
                for j in 0..4 {
                    acc += p[(r, j)] * s_inv[(j, c)];
                }
                k[(r, c)] = acc;
            }
        }

        // x' = x + K y
        for r in 0..7 {
            self.x[r] += k[(r, 0)] * y[0] + k[(r, 1)] * y[1] + k[(r, 2)] * y[2] + k[(r, 3)] * y[3];
        }

        // covariance update; M = I - K H perturbs only columns 0..4:
        // (M P)[r][c] = P[r][c] - sum_{j<4} K[r][j] P[j][c]
        crate::linalg::counters::record(
            crate::linalg::counters::Kernel::Gemm,
            match form {
                CovarianceForm::Joseph => 3 * 2 * (7 * 7 * 4) as u64,
                CovarianceForm::Simple => 2 * (7 * 7 * 4) as u64,
            },
            (49 + 28 + 49) * 8,
        );
        let mut a = Mat7::zeros();
        for r in 0..7 {
            for c in 0..7 {
                let mut acc = p[(r, c)];
                for j in 0..4 {
                    acc -= k[(r, j)] * p[(j, c)];
                }
                a[(r, c)] = acc;
            }
        }
        self.p = match form {
            CovarianceForm::Joseph => {
                // P' = A M' + K R K' = M P M' + K R K' is symmetric by
                // construction: compute the lower triangle and mirror.
                // (A M')[r][c] = A[r][c] - sum_{j<4} A[r][j] K[c][j]
                let rd = consts.r.diagonal();
                let mut out = Mat7::zeros();
                for r in 0..7 {
                    for c in 0..=r {
                        let mut acc = a[(r, c)];
                        for j in 0..4 {
                            acc -= a[(r, j)] * k[(c, j)];
                        }
                        for j in 0..4 {
                            acc += k[(r, j)] * rd[j] * k[(c, j)];
                        }
                        out[(r, c)] = acc;
                        out[(c, r)] = acc;
                    }
                }
                out
            }
            CovarianceForm::Simple => a,
        };
        true
    }

    /// Dense-GEMM measurement update — the paper's library-kernel
    /// formulation (Table II/IV accounting runs; E9.4 ablation).
    pub fn update_dense(&mut self, z: &Vec4, consts: &SortConstants, form: CovarianceForm) -> bool {
        // y = z - H x
        let hx = consts.h.matvec(&self.x);
        let y = crate::linalg::matrix::vec_sub(z, &hx);

        // S = H P H' + R  (4×4 SPD)
        let ph_t: Mat7x4 = self.p.matmul_nt(&consts.h);
        let s: Mat4 = consts.h.matmul(&ph_t).add(&consts.r);

        // K = P H' S^-1  (7×4)
        let s_inv = match chol_inverse(&s) {
            Some(inv) => inv,
            None => return false,
        };
        let k: Mat7x4 = ph_t.matmul(&s_inv);

        // x <- x + K y
        let ky = k.matvec(&y);
        self.x = crate::linalg::matrix::vec_add(&self.x, &ky);

        // covariance update
        let kh: Mat7 = k.matmul(&consts.h);
        let i_kh = Mat7::eye().sub(&kh);
        self.p = match form {
            CovarianceForm::Joseph => {
                let a = i_kh.matmul(&self.p).matmul_nt(&i_kh);
                let b = k.matmul(&consts.r).matmul_nt(&k);
                a.add(&b)
            }
            CovarianceForm::Simple => i_kh.matmul(&self.p),
        };
        true
    }

    /// Innovation covariance diagonal (diagnostics / tests).
    pub fn innovation_cov_diag(&self, consts: &SortConstants) -> [f64; 4] {
        let ph_t: Mat7x4 = self.p.matmul_nt(&consts.h);
        let s: Mat4 = consts.h.matmul(&ph_t).add(&consts.r);
        s.diagonal()
    }
}

/// Convenience: identity-check helper used by multiple test files.
pub fn is_symmetric_psd(p: &Mat7, tol: f64) -> bool {
    if p.asymmetry() > tol {
        return false;
    }
    // SPD check via Cholesky on P + tol*I (PSD boundary tolerance).
    let shifted = p.add(&Mat::<{ DIM_X }, { DIM_X }>::eye().scale(tol));
    crate::linalg::cholesky(&shifted).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn consts() -> SortConstants {
        SortConstants::sort_defaults()
    }

    #[test]
    fn constants_match_sort_spec() {
        let c = consts();
        assert_eq!(c.f[(0, 4)], 1.0);
        assert_eq!(c.f[(2, 6)], 1.0);
        assert_eq!(c.f[(3, 3)], 1.0);
        assert_eq!(c.h[(3, 3)], 1.0);
        assert_eq!(c.h[(3, 4)], 0.0);
        assert_eq!(c.r[(2, 2)], 10.0);
        assert_eq!(c.q[(6, 6)], 0.0001);
        assert_eq!(c.q[(4, 4)], 0.01);
        assert_eq!(c.q[(3, 3)], 1.0);
        assert_eq!(c.p0[(4, 4)], 10000.0);
        assert_eq!(c.p0[(0, 0)], 10.0);
    }

    #[test]
    fn predict_moves_state_by_velocity() {
        let c = consts();
        let mut s = KalmanState::from_measurement(&[100.0, 50.0, 2000.0, 0.5], &c);
        s.x[4] = 3.0;
        s.x[5] = -1.0;
        s.predict(&c);
        assert!((s.x[0] - 103.0).abs() < 1e-12);
        assert!((s.x[1] - 49.0).abs() < 1e-12);
        assert!((s.x[2] - 2000.0).abs() < 1e-12); // ds = 0
    }

    #[test]
    fn negative_area_guard_zeroes_ds() {
        let c = consts();
        let mut s = KalmanState::from_measurement(&[0.0, 0.0, 5.0, 1.0], &c);
        s.x[6] = -10.0; // would drive area negative
        s.predict(&c);
        assert_eq!(s.x[2], 5.0); // area unchanged: guard fired first
        assert_eq!(s.x[6], 0.0);
    }

    #[test]
    fn update_pulls_state_toward_measurement() {
        let c = consts();
        let mut s = KalmanState::from_measurement(&[100.0, 100.0, 1000.0, 1.0], &c);
        s.predict(&c);
        let ok = s.update(&[110.0, 90.0, 1100.0, 1.0], &c, CovarianceForm::Joseph);
        assert!(ok);
        assert!(s.x[0] > 100.0 && s.x[0] <= 110.0);
        assert!(s.x[1] < 100.0 && s.x[1] >= 90.0);
    }

    #[test]
    fn update_shrinks_observed_variance() {
        let c = consts();
        let mut s = KalmanState::from_measurement(&[0.0, 0.0, 100.0, 1.0], &c);
        s.predict(&c);
        let before = s.p.diagonal();
        s.update(&[1.0, 1.0, 101.0, 1.0], &c, CovarianceForm::Joseph);
        let after = s.p.diagonal();
        for i in 0..4 {
            assert!(after[i] < before[i]);
        }
    }

    #[test]
    fn joseph_form_keeps_covariance_symmetric_psd() {
        let c = consts();
        let mut s = KalmanState::from_measurement(&[500.0, 300.0, 5000.0, 0.7], &c);
        for k in 0..500 {
            s.predict(&c);
            let z = [
                500.0 + k as f64,
                300.0 - 0.5 * k as f64,
                5000.0 + 10.0 * k as f64,
                0.7,
            ];
            assert!(s.update(&z, &c, CovarianceForm::Joseph));
            assert!(is_symmetric_psd(&s.p, 1e-9), "frame {k}");
        }
    }

    #[test]
    fn filter_converges_on_constant_velocity_target() {
        let c = consts();
        let mut s = KalmanState::from_measurement(&[0.0, 0.0, 1000.0, 1.0], &c);
        let mut err = f64::MAX;
        for k in 1..40 {
            s.predict(&c);
            let z = [2.0 * k as f64, 1.0 * k as f64, 1000.0, 1.0];
            s.update(&z, &c, CovarianceForm::Joseph);
            err = (s.x[0] - z[0]).abs() + (s.x[1] - z[1]).abs();
        }
        assert!(err < 0.2, "final err {err}");
        assert!((s.x[4] - 2.0).abs() < 0.2); // learned du
        assert!((s.x[5] - 1.0).abs() < 0.2); // learned dv
    }

    #[test]
    fn structured_predict_equals_dense() {
        let c = consts();
        let mut fast = KalmanState::from_measurement(&[123.0, 45.0, 6789.0, 0.71], &c);
        fast.x[4] = 2.5;
        fast.x[5] = -1.25;
        fast.x[6] = 13.0;
        // make P dense and asymmetric-free
        for r in 0..7 {
            for cl in 0..7 {
                fast.p[(r, cl)] = 1.0 + ((r * 7 + cl) as f64) * 0.1 + if r == cl { 20.0 } else { 0.0 };
            }
        }
        fast.p = fast.p.symmetrize();
        let mut dense = fast;
        for _ in 0..50 {
            fast.predict(&c);
            dense.predict_dense(&c);
            for r in 0..7 {
                assert!((fast.x[r] - dense.x[r]).abs() < 1e-9);
                for cl in 0..7 {
                    assert!(
                        (fast.p[(r, cl)] - dense.p[(r, cl)]).abs()
                            < 1e-9 * dense.p[(r, cl)].abs().max(1.0),
                        "P[{r}][{cl}]"
                    );
                }
            }
        }
    }

    #[test]
    fn structured_update_equals_dense() {
        let c = consts();
        for form in [CovarianceForm::Joseph, CovarianceForm::Simple] {
            let mut fast = KalmanState::from_measurement(&[500.0, 300.0, 5000.0, 0.7], &c);
            let mut dense = fast;
            for k in 0..100 {
                let z = [
                    500.0 + 2.0 * k as f64,
                    300.0 - 0.5 * k as f64,
                    5000.0 + 10.0 * k as f64,
                    0.7,
                ];
                fast.predict(&c);
                dense.predict_dense(&c);
                assert!(fast.update(&z, &c, form));
                assert!(dense.update_dense(&z, &c, form));
                for r in 0..7 {
                    assert!(
                        (fast.x[r] - dense.x[r]).abs() < 1e-8,
                        "{form:?} frame {k} x[{r}]: {} vs {}",
                        fast.x[r],
                        dense.x[r]
                    );
                    for cl in 0..7 {
                        assert!(
                            (fast.p[(r, cl)] - dense.p[(r, cl)]).abs() < 1e-8,
                            "{form:?} frame {k} P[{r}][{cl}]"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn structured_update_rejects_non_spd_like_dense() {
        let c = consts();
        let mut s = KalmanState::from_measurement(&[1.0, 1.0, 1.0, 1.0], &c);
        // corrupt covariance: hugely negative diagonal
        for i in 0..4 {
            s.p[(i, i)] = -1e12;
        }
        let before = s;
        assert!(!s.update(&[0.0, 0.0, 1.0, 1.0], &c, CovarianceForm::Joseph));
        // state untouched on failure
        for r in 0..7 {
            assert_eq!(s.x[r], before.x[r]);
        }
    }

    #[test]
    fn simple_form_matches_joseph_mean() {
        let c = consts();
        let mut a = KalmanState::from_measurement(&[10.0, 10.0, 500.0, 1.0], &c);
        let mut b = a;
        a.predict(&c);
        b.predict(&c);
        a.update(&[12.0, 11.0, 510.0, 1.0], &c, CovarianceForm::Joseph);
        b.update(&[12.0, 11.0, 510.0, 1.0], &c, CovarianceForm::Simple);
        for i in 0..7 {
            assert!((a.x[i] - b.x[i]).abs() < 1e-9, "mean must agree");
        }
    }
}
