//! The SORT per-frame update loop — Algorithm 1 of the paper.
//!
//! `Sort::update` is "the only timed function" in the paper's
//! methodology (§III): it runs predict → assign → update → create →
//! output-prep for one frame and returns the confirmed tracks. The
//! struct owns all scratch memory, so after warm-up the per-frame hot
//! path performs no heap allocation — one of the reasons the native
//! path is 40–100× faster than the library-based Python original
//! (Table V).

use super::association::{associate_into, AssociationMethod};
use super::bbox::Bbox;
use super::kalman::{CovarianceForm, SortConstants};
use super::phases::{Phase, PhaseTimer};
use super::scratch::FrameScratch;
use super::tracker::KalmanBoxTracker;
use crate::linalg::lanes::PrecisionTier;

/// Tracker parameters (defaults = the original implementation's).
///
/// `PartialEq` so session runtimes can key warm-engine reuse on "same
/// backend, same parameters".
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SortParams {
    /// Frames a tracker may coast unmatched before culling.
    pub max_age: u32,
    /// Consecutive hits before a track is reported (grace period at
    /// sequence start).
    pub min_hits: u32,
    /// Minimum IoU for a valid match.
    pub iou_threshold: f64,
    /// Assignment algorithm (Hungarian | Greedy ablation).
    pub method: AssociationMethod,
    /// Covariance update form (Joseph | Simple ablation).
    pub cov_form: CovarianceForm,
    /// Collect per-phase timing (Table IV instrumentation).
    pub timing: bool,
    /// Use dense library-style GEMM kernels instead of the structure-
    /// aware fast path (paper-style accounting; E9.4 ablation).
    pub dense_kernels: bool,
    /// Numeric tier the Kalman kernels run in. Informational: each
    /// engine normalizes it at construction to what it actually
    /// executes (`BatchSort<f32>` sets `F32`, every f64 engine sets
    /// `F64`), so `params()` reports the tier that ran. The selector
    /// is [`EngineKind`](crate::engine::EngineKind), not this field.
    pub precision: PrecisionTier,
    /// f32 tier only: relative innovation-residual bound above which a
    /// matched tracker's measurement update is re-run in f64
    /// (per-tracker re-linearization — see `sort/batch.rs`). Ignored
    /// by the f64 engines.
    pub f32_residual_bound: f64,
}

impl Default for SortParams {
    fn default() -> Self {
        SortParams {
            max_age: 1,
            min_hits: 3,
            iou_threshold: 0.3,
            method: AssociationMethod::Hungarian,
            cov_form: CovarianceForm::Joseph,
            timing: true,
            dense_kernels: false,
            precision: PrecisionTier::F64,
            f32_residual_bound: 0.5,
        }
    }
}

/// One confirmed track in a frame's output.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Track {
    /// 1-based stable identity (matches the original's `id + 1`).
    pub id: u64,
    /// Current (post-update) box estimate.
    pub bbox: Bbox,
}

/// Multi-object tracker state for one video stream.
#[derive(Debug)]
pub struct Sort {
    params: SortParams,
    consts: SortConstants,
    trackers: Vec<KalmanBoxTracker>,
    frame_count: u64,
    next_id: u64,
    /// Per-phase timing (merged by harnesses).
    pub phases: PhaseTimer,
    // scratch (reused across frames)
    predicted: Vec<Bbox>,
    scratch: FrameScratch,
    out: Vec<Track>,
}

impl Sort {
    /// New tracker pipeline.
    pub fn new(params: SortParams) -> Self {
        Sort {
            params,
            consts: SortConstants::sort_defaults(),
            trackers: Vec::with_capacity(32),
            frame_count: 0,
            next_id: 0,
            phases: PhaseTimer::new(params.timing),
            predicted: Vec::with_capacity(32),
            scratch: FrameScratch::default(),
            out: Vec::with_capacity(32),
        }
    }

    /// Number of live trackers (confirmed or tentative).
    pub fn n_trackers(&self) -> usize {
        self.trackers.len()
    }

    /// Frames processed so far.
    pub fn frame_count(&self) -> u64 {
        self.frame_count
    }

    /// Tracker parameters.
    pub fn params(&self) -> &SortParams {
        &self.params
    }

    /// Process one frame of detections; must be called every frame
    /// (with an empty slice when there are no detections).
    ///
    /// Returns the confirmed tracks, valid until the next call.
    pub fn update(&mut self, dets: &[Bbox]) -> &[Track] {
        self.frame_count += 1;

        // Split `self` into disjoint field borrows up front so the
        // phase timer can be mutated while the phases borrow the
        // constants — immutable after construction, so no per-frame
        // clone of the five filter matrices.
        let Sort { params, consts, trackers, frame_count, next_id, phases, predicted, scratch, out } =
            self;
        let params = *params;
        let consts: &SortConstants = consts;
        let frame_count = *frame_count;

        // --- 6.2 predict: advance every tracker, cull non-finite ones.
        phases.time(Phase::Predict, || {
            predicted.clear();
            let mut t = 0;
            while t < trackers.len() {
                let b = trackers[t].predict_with(consts, params.dense_kernels);
                if b.is_finite() {
                    predicted.push(b);
                    t += 1;
                } else {
                    // same effect as the original's NaN row compression
                    trackers.remove(t);
                }
            }
        });

        // working set of predict: per tracker x(7)+P(49) doubles + the
        // shared constants F,Q (2x49)
        let n_trk = trackers.len() as u64;
        phases.add_ws(Phase::Predict, n_trk * 56 * 8 + 98 * 8);

        // --- 6.3 assignment
        let predicted: &Vec<Bbox> = predicted;
        phases.time(Phase::Assign, || {
            associate_into(dets, predicted, params.iou_threshold, params.method, scratch);
        });
        // working set of assignment: det + tracker boxes + the IoU/cost matrix
        let (nd, nt) = (dets.len() as u64, predicted.len() as u64);
        phases.add_ws(Phase::Assign, (4 * nd + 4 * nt + nd * nt) * 8);
        let result = &scratch.result;

        // --- 6.4 update matched trackers with their detections
        phases.time(Phase::Update, || {
            for &(d, t) in &result.matched {
                trackers[t].update_with(&dets[d], consts, params.cov_form, params.dense_kernels);
            }
        });
        // working set of update: per matched tracker x(7)+P(49)+z(4)
        // doubles + the shared constants H,R (28+16)
        phases.add_ws(Phase::Update, result.matched.len() as u64 * 60 * 8 + 44 * 8);

        // --- 6.6 create new trackers from unmatched detections
        phases.time(Phase::CreateNew, || {
            for &d in &result.unmatched_dets {
                trackers.push(KalmanBoxTracker::new(*next_id, &dets[d], consts));
                *next_id += 1;
            }
        });
        phases.add_ws(Phase::CreateNew, result.unmatched_dets.len() as u64 * 60 * 8);

        // --- 6.7 prepare output + cull expired trackers
        phases.time(Phase::Output, || {
            out.clear();
            let mut i = trackers.len();
            while i > 0 {
                i -= 1;
                let trk = &trackers[i];
                if trk.time_since_update < 1
                    && (trk.hit_streak >= params.min_hits || frame_count <= params.min_hits as u64)
                {
                    out.push(Track { id: trk.id + 1, bbox: trk.state_bbox() });
                }
                if trk.time_since_update > params.max_age {
                    trackers.remove(i);
                }
            }
        });
        let n_after = trackers.len() as u64;
        phases.add_ws(Phase::Output, n_after * 11 * 8);
        out
    }

    /// Drop all tracker state but keep scratch buffers (stream reuse).
    pub fn reset(&mut self) {
        self.trackers.clear();
        self.frame_count = 0;
        self.next_id = 0;
        self.phases.reset();
    }

    /// Snapshot the full tracking state (engine migration; see
    /// [`super::snapshot`]). Exact: every `f64` crosses by value.
    pub fn export_state(&self) -> super::snapshot::EngineState {
        super::snapshot::EngineState {
            frame_count: self.frame_count,
            next_id: self.next_id,
            trackers: self
                .trackers
                .iter()
                .map(super::snapshot::TrackerSnapshot::from_tracker)
                .collect(),
        }
    }

    /// Replace all tracking state with `state` (scratch buffers kept).
    pub fn import_state(&mut self, state: &super::snapshot::EngineState) {
        self.trackers.clear();
        self.trackers.extend(state.trackers.iter().map(|s| s.to_tracker()));
        self.frame_count = state.frame_count;
        self.next_id = state.next_id;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn b(x1: f64, y1: f64, x2: f64, y2: f64) -> Bbox {
        Bbox::new(x1, y1, x2, y2)
    }

    /// Three objects on linear trajectories (matches the python golden
    /// scenario's seeds/velocities, without the jitter).
    fn frame_boxes(k: usize) -> Vec<Bbox> {
        let seeds = [
            [10.0, 20.0, 60.0, 140.0],
            [200.0, 50.0, 260.0, 170.0],
            [400.0, 300.0, 470.0, 420.0],
        ];
        let vel = [[3.0, 1.5], [-2.0, 0.5], [1.0, -2.0]];
        (0..3)
            .map(|i| {
                b(
                    seeds[i][0] + vel[i][0] * k as f64,
                    seeds[i][1] + vel[i][1] * k as f64,
                    seeds[i][2] + vel[i][0] * k as f64,
                    seeds[i][3] + vel[i][1] * k as f64,
                )
            })
            .collect()
    }

    #[test]
    fn reports_tracks_within_grace_period() {
        let mut s = Sort::new(SortParams::default());
        for k in 0..3 {
            let tracks = s.update(&frame_boxes(k)).to_vec();
            assert_eq!(tracks.len(), 3, "frame {k}");
        }
    }

    #[test]
    fn ids_are_stable_over_long_run() {
        let mut s = Sort::new(SortParams::default());
        let mut ids = std::collections::BTreeSet::new();
        for k in 0..50 {
            for t in s.update(&frame_boxes(k)) {
                ids.insert(t.id);
            }
        }
        assert_eq!(ids.into_iter().collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn empty_frames_kill_trackers_after_max_age() {
        let mut s = Sort::new(SortParams { min_hits: 1, ..Default::default() });
        for k in 0..5 {
            s.update(&frame_boxes(k));
        }
        assert_eq!(s.n_trackers(), 3);
        s.update(&[]); // coast 1 (<= max_age: kept)
        assert_eq!(s.n_trackers(), 3);
        s.update(&[]); // coast 2 (> max_age: culled)
        assert_eq!(s.n_trackers(), 0);
    }

    #[test]
    fn track_survives_single_dropout_and_reacquires() {
        let mut s = Sort::new(SortParams { min_hits: 1, ..Default::default() });
        for k in 0..5 {
            s.update(&frame_boxes(k));
        }
        s.update(&[]);
        let tracks = s.update(&frame_boxes(6)).to_vec();
        assert_eq!(tracks.len(), 3);
        let mut ids: Vec<_> = tracks.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2, 3]); // no id churn
    }

    #[test]
    fn new_object_gets_fresh_id() {
        let mut s = Sort::new(SortParams { min_hits: 1, ..Default::default() });
        for k in 0..3 {
            s.update(&frame_boxes(k));
        }
        let mut boxes = frame_boxes(3);
        boxes.push(b(700.0, 700.0, 760.0, 800.0));
        s.update(&boxes);
        let mut boxes = frame_boxes(4);
        boxes.push(b(700.0, 700.0, 760.0, 800.0));
        let tracks = s.update(&boxes).to_vec();
        assert_eq!(tracks.len(), 4);
        assert!(tracks.iter().any(|t| t.id == 4));
    }

    #[test]
    fn tentative_tracks_not_reported_after_grace() {
        // one spurious detection at frame 5 must not be reported
        // (hit_streak 0 < min_hits 3 and frame_count > min_hits)
        let mut s = Sort::new(SortParams::default());
        for k in 0..5 {
            s.update(&frame_boxes(k));
        }
        let mut boxes = frame_boxes(5);
        boxes.push(b(900.0, 900.0, 950.0, 980.0));
        let tracks = s.update(&boxes).to_vec();
        assert_eq!(tracks.len(), 3, "ghost must be suppressed");
    }

    #[test]
    fn update_must_be_called_every_frame() {
        let mut s = Sort::new(SortParams::default());
        let out = s.update(&[]);
        assert!(out.is_empty());
        assert_eq!(s.frame_count(), 1);
    }

    #[test]
    fn reset_clears_state() {
        let mut s = Sort::new(SortParams::default());
        s.update(&frame_boxes(0));
        assert!(s.n_trackers() > 0);
        s.reset();
        assert_eq!(s.n_trackers(), 0);
        assert_eq!(s.frame_count(), 0);
        // ids restart
        s.update(&frame_boxes(0));
        let tracks = s.update(&frame_boxes(1)).to_vec();
        assert!(tracks.iter().all(|t| t.id <= 3));
    }

    #[test]
    fn phase_timer_records_all_phases() {
        let mut s = Sort::new(SortParams::default());
        for k in 0..10 {
            s.update(&frame_boxes(k));
        }
        assert_eq!(s.phases.get(Phase::Predict).count, 10);
        assert_eq!(s.phases.get(Phase::Assign).count, 10);
        if cfg!(feature = "counters") {
            assert!(s.phases.get(Phase::Update).counters.total().flops > 0);
        }
    }

    #[test]
    fn crossing_objects_keep_ids_via_hungarian() {
        // two objects crossing paths; optimal association should keep
        // identities through the crossing
        let mut s = Sort::new(SortParams { min_hits: 1, ..Default::default() });
        let mut id_at_start = Vec::new();
        for k in 0..30 {
            let x_a = 10.0 + 5.0 * k as f64; // moves right
            let x_b = 160.0 - 5.0 * k as f64; // moves left
            let boxes = vec![
                b(x_a, 10.0, x_a + 20.0, 50.0),
                b(x_b, 12.0, x_b + 20.0, 52.0),
            ];
            let tracks = s.update(&boxes).to_vec();
            if k == 2 {
                id_at_start = tracks.iter().map(|t| t.id).collect();
            }
        }
        let final_tracks = s.update(&[b(165.0, 10.0, 185.0, 50.0), b(5.0, 12.0, 25.0, 52.0)]);
        for t in final_tracks {
            assert!(id_at_start.contains(&t.id), "identity churn at crossing");
        }
    }
}
