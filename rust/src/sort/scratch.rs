//! [`FrameScratch`] — every heap buffer the per-frame hot loop needs,
//! owned by the engine and reused across frames.
//!
//! The paper's regime is "low actual work, high overhead": at 7×7
//! matrices and ≤13×13 cost matrices, a single `malloc` costs more
//! than the arithmetic it feeds. So the frame loop is allocation-free
//! in steady state — after a warm-up period in which these buffers
//! grow to the stream's high-water marks, `Sort::update` and
//! `BatchSort::update` never touch the allocator again. The contract
//! is pinned by `rust/tests/integration_alloc.rs` with a counting
//! global allocator; see ARCHITECTURE.md §"Hot-path memory discipline"
//! for what is allowed to allocate and when.
//!
//! One `FrameScratch` bundles the association working set:
//! * the IoU matrix and the negated cost matrix,
//! * the fast-path row/col candidate counts,
//! * the raw assignment pairs (fast path, Hungarian, or greedy),
//! * the matched/unmatched flags and the [`AssociationResult`] vectors,
//! * the [`HungarianScratch`] dual potentials / augmenting-path state.

use super::association::AssociationResult;
use super::hungarian::HungarianScratch;

/// Reusable per-frame buffers for one tracking pipeline.
///
/// Fields are crate-private: engines own one and thread it through
/// [`super::association::associate_into`]; the association output is
/// read back via [`Self::result`].
#[derive(Debug, Default)]
pub struct FrameScratch {
    /// Row-major `dets x trackers` IoU matrix.
    pub(crate) iou: Vec<f64>,
    /// Negated IoU (the Hungarian minimizes cost).
    pub(crate) cost: Vec<f64>,
    /// Fast-path candidate count per detection row.
    pub(crate) row_count: Vec<usize>,
    /// Fast-path candidate count per tracker column.
    pub(crate) col_count: Vec<usize>,
    /// Raw `(det, trk)` pairs before the threshold post-filter.
    pub(crate) pairs: Vec<(usize, usize)>,
    /// Hungarian `row -> Option<col>` assignment output.
    pub(crate) assignment: Vec<Option<usize>>,
    /// Which detections ended up matched.
    pub(crate) det_matched: Vec<bool>,
    /// Which trackers ended up matched.
    pub(crate) trk_matched: Vec<bool>,
    /// Greedy-fallback row-used flags.
    pub(crate) greedy_rows: Vec<bool>,
    /// Greedy-fallback column-used flags.
    pub(crate) greedy_cols: Vec<bool>,
    /// Hungarian solver state (duals, augmenting paths, transpose).
    pub(crate) hungarian: HungarianScratch,
    /// The association output vectors, cleared and refilled per frame.
    pub(crate) result: AssociationResult,
}

impl FrameScratch {
    /// The association result of the most recent
    /// [`super::association::associate_into`] call.
    pub fn result(&self) -> &AssociationResult {
        &self.result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::association::{associate_into, AssociationMethod};
    use crate::sort::Bbox;

    #[test]
    fn buffers_are_reused_across_calls() {
        let mut s = FrameScratch::default();
        let d = vec![Bbox::new(0.0, 0.0, 10.0, 10.0), Bbox::new(50.0, 50.0, 60.0, 60.0)];
        let t = vec![Bbox::new(0.0, 1.0, 10.0, 11.0), Bbox::new(50.0, 51.0, 60.0, 61.0)];
        associate_into(&d, &t, 0.3, AssociationMethod::Hungarian, &mut s);
        let matched_first = s.result().matched.clone();
        let cap = s.iou.capacity();
        associate_into(&d, &t, 0.3, AssociationMethod::Hungarian, &mut s);
        assert_eq!(s.result().matched, matched_first);
        assert_eq!(s.iou.capacity(), cap, "IoU buffer must be reused");
    }

    #[test]
    fn result_is_cleared_between_frames() {
        let mut s = FrameScratch::default();
        let d = vec![Bbox::new(0.0, 0.0, 10.0, 10.0)];
        let t = vec![Bbox::new(0.0, 0.0, 10.0, 10.0)];
        associate_into(&d, &t, 0.3, AssociationMethod::Hungarian, &mut s);
        assert_eq!(s.result().matched.len(), 1);
        associate_into(&[], &t, 0.3, AssociationMethod::Hungarian, &mut s);
        assert!(s.result().matched.is_empty(), "stale matches must not leak");
        assert_eq!(s.result().unmatched_trks, vec![0]);
    }
}
