//! Analytic hardware-counter model — the Table III substitution.
//!
//! The paper characterizes the Python SORT with perf counters
//! (instructions, IPC, TLB/LLC MPKI, bandwidth). Bare-metal counters
//! are not reliable in this virtualized testbed, so Table III is
//! regenerated two ways:
//!
//! 1. **Analytic** ([`estimate`]): instructions are estimated from the
//!    instrumented linalg counters (flops, bytes, calls) with
//!    per-kernel cost factors; cache behavior follows from the working
//!    set (a tracker is < 1 KiB — it *cannot* miss in LLC, which is the
//!    paper's low-MPKI finding); bandwidth = measured bytes over
//!    measured wall time against a nominal peak.
//! 2. **Measured** (optional): if a usable `perf` is present, the
//!    Table III bench shells out to `perf stat` and reports real
//!    counters next to the model.
//!
//! Both paths print the same row format as the paper's Table III.

use crate::linalg::counters::{CounterSnapshot, Kernel};
use std::process::Command;
use std::time::Duration;

/// Modeled (or measured) Table III row.
#[derive(Debug, Clone)]
pub struct CounterEstimate {
    /// Total dynamic instructions (estimated).
    pub instructions: f64,
    /// Wall time of the measured region.
    pub time: Duration,
    /// Instructions per cycle at the nominal frequency.
    pub ipc: f64,
    /// TLB misses per kilo-instruction (modeled).
    pub tlb_mpki: f64,
    /// LLC misses per kilo-instruction (modeled).
    pub llc_mpki: f64,
    /// Fraction of peak DRAM bandwidth used.
    pub bw_usage: f64,
}

/// Nominal CPU frequency for IPC conversion (Hz). The measured region
/// is single-threaded, so the single-active-core turbo clock (3.7 GHz
/// on the paper's SKX) is the right divisor.
pub const NOMINAL_HZ: f64 = 3.7e9;

/// Nominal peak DRAM bandwidth (bytes/s) — 6-channel DDR4-2666 SKX.
pub const PEAK_BW: f64 = 128e9;

/// Per-kernel instruction cost factors: instructions ≈
/// `flops * ipf + calls * dispatch`.
///
/// Scalar f64 FP with fused loads runs ~1.6 instr/flop in these loop
/// nests (load, load, fma-or-mul+add, store amortized); per-call
/// dispatch covers loop setup and the counter bump itself.
fn kernel_cost(k: Kernel) -> (f64, f64) {
    match k {
        Kernel::Gemm | Kernel::Gemv => (1.6, 25.0),
        Kernel::Cholesky | Kernel::TriSolve | Kernel::Inverse => (2.2, 40.0),
        Kernel::Transpose | Kernel::MatCopy => (0.9, 15.0),
        Kernel::Sqrt => (12.0, 10.0), // sqrt latency ≫ 1 instr
        Kernel::Hungarian => (3.0, 60.0),
        _ => (1.2, 12.0),
    }
}

/// Estimate Table III counters from a linalg counter snapshot plus the
/// wall time of the counted region.
pub fn estimate(counters: &CounterSnapshot, wall: Duration) -> CounterEstimate {
    let mut instructions = 0.0;
    for k in Kernel::ALL {
        let s = counters.get(k);
        let (ipf, disp) = kernel_cost(k);
        instructions += s.flops as f64 * ipf + s.calls as f64 * disp;
    }
    // non-linalg bookkeeping (lifecycle, I/O prep): the paper's profile
    // attributes ~10% of update() outside matrix kernels
    instructions *= 1.10;

    let secs = wall.as_secs_f64().max(1e-12);
    let cycles = secs * NOMINAL_HZ;
    let ipc = instructions / cycles;

    // Working set per stream = a handful of 7x7 f64 matrices (< 4 KiB):
    // it lives in L1; LLC/TLB misses come only from cold starts and the
    // streaming detection input, amortized to ~0 per kilo-instruction.
    // Model them proportional to operand traffic.
    let bytes = counters.total().bytes as f64;
    let llc_misses = (bytes / 64.0) * 0.002;
    let llc_mpki = llc_misses / (instructions / 1000.0);
    let tlb_mpki = (bytes / 4096.0) * 0.004 / (instructions / 1000.0);

    // Only LLC misses reach DRAM: operand traffic is cache-resident
    // (that is the paper's Table III point), so modeled bandwidth is
    // miss traffic over wall time.
    let bw_usage = (llc_misses * 64.0 / secs) / PEAK_BW;

    CounterEstimate {
        instructions,
        time: wall,
        ipc,
        tlb_mpki,
        llc_mpki,
        bw_usage,
    }
}

/// Raw counters parsed from `perf stat`.
#[derive(Debug, Clone, Default)]
pub struct PerfStat {
    /// instructions retired
    pub instructions: Option<f64>,
    /// cpu cycles
    pub cycles: Option<f64>,
}

impl PerfStat {
    /// IPC when both counters are present.
    pub fn ipc(&self) -> Option<f64> {
        match (self.instructions, self.cycles) {
            (Some(i), Some(c)) if c > 0.0 => Some(i / c),
            _ => None,
        }
    }
}

/// Try to run `cmd` under `perf stat`; `None` when perf is unusable
/// (common in containers without perf_event access).
pub fn run_under_perf(cmd: Command) -> Option<PerfStat> {
    let prog = cmd.get_program().to_os_string();
    let args: Vec<_> = cmd.get_args().map(|a| a.to_os_string()).collect();
    let out = Command::new("perf")
        .arg("stat")
        .args(["-e", "instructions,cycles", "-x", ","])
        .arg("--")
        .arg(prog)
        .args(args)
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let text = String::from_utf8_lossy(&out.stderr);
    let mut stat = PerfStat::default();
    for line in text.lines() {
        let mut fields = line.split(',');
        let val = fields.next().unwrap_or("").trim().replace('_', "");
        let _unit = fields.next();
        let name = fields.next().unwrap_or("").trim();
        if let Ok(v) = val.parse::<f64>() {
            if name.contains("instructions") {
                stat.instructions = Some(v);
            } else if name.contains("cycles") {
                stat.cycles = Some(v);
            }
        }
    }
    if stat.instructions.is_none() && stat.cycles.is_none() {
        None
    } else {
        Some(stat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    #[cfg(feature = "counters")]
    use crate::linalg::counters::{record, reset_counters, snapshot};

    #[test]
    #[cfg(feature = "counters")]
    fn estimate_scales_with_flops() {
        reset_counters();
        record(Kernel::Gemm, 1_000_000, 100_000);
        let small = estimate(&snapshot(), Duration::from_millis(10));
        reset_counters();
        record(Kernel::Gemm, 10_000_000, 1_000_000);
        let big = estimate(&snapshot(), Duration::from_millis(10));
        assert!(big.instructions > 5.0 * small.instructions);
        assert!(big.ipc > small.ipc);
    }

    #[test]
    #[cfg(feature = "counters")]
    fn low_mpki_for_tiny_working_set() {
        reset_counters();
        record(Kernel::Gemm, 1_000_000, 500_000);
        let e = estimate(&snapshot(), Duration::from_millis(5));
        // the paper's Table III: TLB 0.136, LLC 0.059 — "low"
        assert!(e.llc_mpki < 1.0, "{e:?}");
        assert!(e.tlb_mpki < 1.0, "{e:?}");
        assert!(e.bw_usage < 0.01, "{e:?}"); // paper: 0.015%
    }

    #[test]
    #[cfg(feature = "counters")]
    fn ipc_in_plausible_range() {
        reset_counters();
        // ~47k FPS native: 5500 frames of ~40k flops in ~0.117 s
        record(Kernel::Gemm, 5500 * 40_000, 5500 * 200_000);
        let e = estimate(&snapshot(), Duration::from_secs_f64(0.117));
        assert!(e.ipc > 0.3 && e.ipc < 6.0, "{}", e.ipc);
    }

    #[test]
    fn perf_parse_shapes() {
        // run_under_perf on a missing binary must be None, not panic
        let got = run_under_perf(Command::new("/nonexistent-binary-xyz"));
        assert!(got.is_none() || got.is_some()); // no panic; env-dependent
    }
}
