//! `TrackerEngine` — the one abstraction every tracker backend sits
//! behind.
//!
//! The repo grew five tracker backends with identical semantics but
//! different execution strategies (and, for one, a different numeric
//! tier):
//!
//! * [`Sort`] (`native`) — the single-core structure-aware pipeline,
//!   the paper's "well-optimized serial C" analog;
//! * [`BatchSort`] (`batch`) — the same math over structure-of-arrays
//!   lanes swept by explicit SIMD lane kernels, one counter event per
//!   frame, zero steady-state allocation, bit-identical to `native`;
//! * [`BatchSortF32`] (`batchf32`) — the batch engine's f32 precision
//!   tier: ~half the state traffic and twice the lane width, guarded
//!   by per-tracker f64 re-linearization on large innovation
//!   residuals (approximate, not bit-identical — see
//!   [`crate::linalg::lanes`]);
//! * [`ParallelSort`] (`strong`) — intra-frame fork-join parallelism,
//!   the paper's (losing) OpenMP strong-scaling port;
//! * [`TrackerBank`] (`xla`) — fixed-slot state arrays with the dense
//!   algebra dispatched to the AOT-compiled JAX/Pallas kernels (or the
//!   built-in reference interpreter when the PJRT backend is absent).
//!
//! The coordinator, CLI, benches and tests program against this trait
//! only; backends are chosen by [`EngineKind`] and injected, never
//! constructed inline. Adding a backend (GPU, simulator-driven) means
//! implementing four methods and one enum arm.
//!
//! Equivalence between the f64 engines on shared inputs is pinned by
//! `rust/tests/integration_engines.rs` (the f32 tier is pinned there
//! too, to determinism and loose agreement rather than equality).

use crate::coordinator::strong::ParallelSort;
use crate::runtime::{TrackerBank, XlaRuntime};
use crate::sort::{BatchSort, BatchSortF32, Bbox, PhaseTimer, Sort, SortParams, Track};

pub use crate::sort::{EngineState, TrackerSnapshot};

/// A multi-object tracker backend for one video stream.
///
/// Implementations own all per-stream state (filter states, lifecycle
/// counters, scratch buffers). `update` must be called once per frame,
/// in order, with an empty slice when the frame has no detections.
///
/// The minimal track-one-stream loop:
///
/// ```
/// use smalltrack::data::synth::{generate_sequence, SynthConfig};
/// use smalltrack::engine::EngineKind;
/// use smalltrack::sort::{Bbox, SortParams};
///
/// let synth = generate_sequence(&SynthConfig::mot15("ENG", 40, 5, 3));
/// let mut engine = EngineKind::Native.build(SortParams::default()).unwrap();
/// let mut boxes: Vec<Bbox> = Vec::new();
/// let mut track_frames = 0;
/// for frame in &synth.sequence.frames {
///     boxes.clear();
///     boxes.extend(frame.detections.iter().map(|d| d.bbox));
///     track_frames += engine.update(&boxes).len();
/// }
/// assert!(track_frames > 0);
/// engine.reset(); // ready for the next stream, scratch kept warm
/// assert_eq!(engine.n_trackers(), 0);
/// ```
pub trait TrackerEngine: Send {
    /// Process one frame of detections; returns the confirmed tracks,
    /// valid until the next call.
    fn update(&mut self, dets: &[Bbox]) -> &[Track];

    /// Number of live trackers (confirmed or tentative).
    fn n_trackers(&self) -> usize;

    /// Per-phase timing instrumentation, when the backend collects it.
    fn phases(&self) -> Option<&PhaseTimer>;

    /// Drop all tracker state (ids restart) but keep warm scratch
    /// buffers, so a worker can reuse one engine across streams.
    fn reset(&mut self);

    /// Stable backend name (`native` | `batch` | `batchf32` |
    /// `strong` | `xla`).
    fn name(&self) -> &'static str;

    /// Snapshot the full tracking state in engine-neutral form
    /// ([`EngineState`]) so a live stream can migrate to another
    /// backend mid-run. `None` when the backend does not support
    /// migration (the fixed-slot `xla` bank keeps state device-side).
    ///
    /// f64 backends export exactly (every value crosses by bits); the
    /// f32 tier widens losslessly.
    fn export_state(&self) -> Option<EngineState> {
        None
    }

    /// Replace this engine's tracking state with `state` (the receiving
    /// half of a migration); scratch buffers are kept warm. Returns
    /// `false` when the backend does not support migration — the
    /// engine's state is untouched in that case.
    fn import_state(&mut self, _state: &EngineState) -> bool {
        false
    }
}

impl TrackerEngine for Sort {
    fn update(&mut self, dets: &[Bbox]) -> &[Track] {
        Sort::update(self, dets)
    }

    fn n_trackers(&self) -> usize {
        Sort::n_trackers(self)
    }

    fn phases(&self) -> Option<&PhaseTimer> {
        Some(&self.phases)
    }

    fn reset(&mut self) {
        Sort::reset(self)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn export_state(&self) -> Option<EngineState> {
        Some(Sort::export_state(self))
    }

    fn import_state(&mut self, state: &EngineState) -> bool {
        Sort::import_state(self, state);
        true
    }
}

impl TrackerEngine for BatchSort {
    fn update(&mut self, dets: &[Bbox]) -> &[Track] {
        BatchSort::update(self, dets)
    }

    fn n_trackers(&self) -> usize {
        BatchSort::n_trackers(self)
    }

    fn phases(&self) -> Option<&PhaseTimer> {
        Some(&self.phases)
    }

    fn reset(&mut self) {
        BatchSort::reset(self)
    }

    fn name(&self) -> &'static str {
        "batch"
    }

    fn export_state(&self) -> Option<EngineState> {
        Some(BatchSort::export_state(self))
    }

    fn import_state(&mut self, state: &EngineState) -> bool {
        BatchSort::import_state(self, state);
        true
    }
}

impl TrackerEngine for BatchSortF32 {
    fn update(&mut self, dets: &[Bbox]) -> &[Track] {
        BatchSortF32::update(self, dets)
    }

    fn n_trackers(&self) -> usize {
        BatchSortF32::n_trackers(self)
    }

    fn phases(&self) -> Option<&PhaseTimer> {
        Some(&self.phases)
    }

    fn reset(&mut self) {
        BatchSortF32::reset(self)
    }

    fn name(&self) -> &'static str {
        "batchf32"
    }

    fn export_state(&self) -> Option<EngineState> {
        Some(BatchSortF32::export_state(self))
    }

    fn import_state(&mut self, state: &EngineState) -> bool {
        BatchSortF32::import_state(self, state);
        true
    }
}

impl TrackerEngine for ParallelSort {
    fn update(&mut self, dets: &[Bbox]) -> &[Track] {
        ParallelSort::update(self, dets)
    }

    fn n_trackers(&self) -> usize {
        ParallelSort::n_trackers(self)
    }

    fn phases(&self) -> Option<&PhaseTimer> {
        Some(&self.phases)
    }

    fn reset(&mut self) {
        ParallelSort::reset(self)
    }

    fn name(&self) -> &'static str {
        "strong"
    }

    fn export_state(&self) -> Option<EngineState> {
        Some(ParallelSort::export_state(self))
    }

    fn import_state(&mut self, state: &EngineState) -> bool {
        ParallelSort::import_state(self, state);
        true
    }
}

impl TrackerEngine for TrackerBank {
    fn update(&mut self, dets: &[Bbox]) -> &[Track] {
        // The reference interpreter cannot fail on well-formed geometry;
        // a real PJRT execution error here means the artifacts and the
        // bank disagree on shapes, which is unrecoverable state
        // corruption — surface it loudly.
        TrackerBank::update(self, dets).expect("tracker-bank kernel dispatch failed")
    }

    fn n_trackers(&self) -> usize {
        TrackerBank::n_trackers(self)
    }

    fn phases(&self) -> Option<&PhaseTimer> {
        None
    }

    fn reset(&mut self) {
        TrackerBank::reset(self)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Which backend to build — the injectable engine selector.
///
/// `Copy` so it can cross thread boundaries freely (worker threads build
/// their own engine instances; engines themselves are never shared).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Single-core structure-aware `Sort`.
    Native,
    /// Batched SoA `BatchSort` (explicit SIMD lane sweeps over all
    /// trackers, zero steady-state allocation, bit-identical to
    /// `Native`).
    Batch,
    /// The batch engine's opt-in f32 precision tier (`BatchSortF32`):
    /// faster and half the state traffic, approximate rather than
    /// bit-identical, with residual-gated per-tracker f64 fallback.
    BatchF32,
    /// Intra-frame fork-join `ParallelSort` with `threads` threads.
    Strong {
        /// Fork-join width per frame.
        threads: usize,
    },
    /// The XLA tracker bank (AOT kernels or reference interpreter).
    Xla,
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;

    /// Parse a self-contained engine spec: `native` | `batch` |
    /// `batchf32` | `strong[:N]` | `xla`, where `N` is the strong backend's
    /// fork-join width (`strong` alone defaults to 2, matching the
    /// historical CLI default; widths below 1 clamp to 1).
    ///
    /// This is the preferred form everywhere an engine is named — the
    /// spec carries its own parameters, so no side-channel `threads`
    /// argument rides along (`"strong:8".parse()` replaces
    /// `EngineKind::parse("strong", 8)`).
    fn from_str(spec: &str) -> Result<EngineKind, Self::Err> {
        let (name, arg) = match spec.split_once(':') {
            Some((name, arg)) => (name, Some(arg)),
            None => (spec, None),
        };
        match (name, arg) {
            ("native", None) => Ok(EngineKind::Native),
            ("batch", None) => Ok(EngineKind::Batch),
            ("batchf32", None) => Ok(EngineKind::BatchF32),
            ("xla", None) => Ok(EngineKind::Xla),
            ("strong", None) => Ok(EngineKind::Strong { threads: 2 }),
            ("strong", Some(n)) => {
                let threads: usize = n.parse().map_err(|_| {
                    anyhow::anyhow!("bad thread count '{n}' in engine spec '{spec}' (expected strong:N)")
                })?;
                Ok(EngineKind::Strong { threads: threads.max(1) })
            }
            _ => anyhow::bail!(
                "unknown engine spec '{spec}' (expected native|batch|batchf32|strong[:N]|xla)"
            ),
        }
    }
}

impl EngineKind {
    /// Parse a CLI `--engine` value. `threads` parameterizes the bare
    /// `strong` backend (ignored by the others).
    ///
    /// Deprecated in favor of the [`std::str::FromStr`] spec form
    /// (`"strong:8".parse()`), which needs no side-channel `threads`
    /// argument; this two-arg form is kept so legacy
    /// `--engine strong --threads N` invocations keep parsing.
    /// Spec-form strings (anything containing `:`) are accepted here
    /// too and take precedence over `threads`.
    pub fn parse(name: &str, threads: usize) -> crate::Result<EngineKind> {
        match name {
            // the one case the legacy side-channel still decides
            "strong" => Ok(EngineKind::Strong { threads: threads.max(1) }),
            spec => spec.parse(),
        }
    }

    /// Stable backend name.
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Batch => "batch",
            EngineKind::BatchF32 => "batchf32",
            EngineKind::Strong { .. } => "strong",
            EngineKind::Xla => "xla",
        }
    }

    /// Whether this tier can exchange tracker state with other tiers
    /// via [`EngineState`] — i.e. whether it is a valid source *and*
    /// target for a live session migration. Everything but the XLA
    /// bank qualifies; the bank keeps device-resident state it cannot
    /// export or import.
    pub fn supports_migration(&self) -> bool {
        !matches!(self, EngineKind::Xla)
    }

    /// Self-contained spec string that round-trips through
    /// [`std::str::FromStr`]: `native` | `batch` | `batchf32` |
    /// `strong:N` | `xla`.
    pub fn spec(&self) -> String {
        match self {
            EngineKind::Strong { threads } => format!("strong:{threads}"),
            other => other.label().to_string(),
        }
    }

    /// Construct a fresh engine of this kind.
    ///
    /// For `Xla` this opens a private [`XlaRuntime`] — cheap with the
    /// reference interpreter (one manifest stat/parse), but callers
    /// building many bank engines (or using a compiled PJRT backend,
    /// where construction means compiling HLO) should share one runtime
    /// via [`Self::build_with_runtime`].
    pub fn build(&self, params: SortParams) -> crate::Result<Box<dyn TrackerEngine>> {
        Ok(match self {
            EngineKind::Native => Box::new(Sort::new(params)),
            EngineKind::Batch => Box::new(BatchSort::<f64>::new(params)),
            EngineKind::BatchF32 => Box::new(BatchSortF32::new(params)),
            EngineKind::Strong { threads } => Box::new(ParallelSort::new(params, *threads)),
            EngineKind::Xla => Box::new(TrackerBank::new(&XlaRuntime::new()?, params)?),
        })
    }

    /// [`Self::build`] reusing a caller-owned kernel runtime for the
    /// `Xla` backend (the other kinds don't need one).
    pub fn build_with_runtime(
        &self,
        rt: &XlaRuntime,
        params: SortParams,
    ) -> crate::Result<Box<dyn TrackerEngine>> {
        match self {
            EngineKind::Xla => Ok(Box::new(TrackerBank::new(rt, params)?)),
            other => other.build(params),
        }
    }

    /// The four f64 kinds (test/bench equivalence sweeps — every one
    /// of these must emit identical tracks on shared inputs; the
    /// approximate f32 tier is deliberately excluded, see
    /// [`Self::all_tiers`]).
    pub fn all(threads: usize) -> [EngineKind; 4] {
        [
            EngineKind::Native,
            EngineKind::Batch,
            EngineKind::Strong { threads },
            EngineKind::Xla,
        ]
    }

    /// Every backend including the approximate f32 tier — for sweeps
    /// that only need each engine to be self-consistent (build, track,
    /// reset-reproducibility), not cross-engine identical.
    pub fn all_tiers(threads: usize) -> [EngineKind; 5] {
        [
            EngineKind::Native,
            EngineKind::Batch,
            EngineKind::BatchF32,
            EngineKind::Strong { threads },
            EngineKind::Xla,
        ]
    }
}

/// Track one stored sequence through an engine; returns
/// `(frames, track_frames)`. The shared runner every scheduler mode and
/// bench uses, so all backends are measured through the same loop.
pub fn run_sequence(
    engine: &mut dyn TrackerEngine,
    seq: &crate::data::mot::Sequence,
) -> (u64, u64) {
    let mut boxes: Vec<Bbox> = Vec::with_capacity(16);
    let mut tracks_out = 0u64;
    for frame in &seq.frames {
        boxes.clear();
        boxes.extend(frame.detections.iter().map(|d| d.bbox));
        tracks_out += engine.update(&boxes).len() as u64;
    }
    (seq.n_frames() as u64, tracks_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_sequence, SynthConfig};

    fn params() -> SortParams {
        SortParams { timing: false, ..Default::default() }
    }

    #[test]
    fn parse_all_kinds() {
        // the legacy two-arg form keeps parsing unchanged
        assert_eq!(EngineKind::parse("native", 4).unwrap(), EngineKind::Native);
        assert_eq!(EngineKind::parse("batch", 4).unwrap(), EngineKind::Batch);
        assert_eq!(EngineKind::parse("batchf32", 4).unwrap(), EngineKind::BatchF32);
        assert_eq!(EngineKind::parse("strong", 4).unwrap(), EngineKind::Strong { threads: 4 });
        assert_eq!(EngineKind::parse("strong", 0).unwrap(), EngineKind::Strong { threads: 1 });
        assert_eq!(EngineKind::parse("xla", 1).unwrap(), EngineKind::Xla);
        assert!(EngineKind::parse("gpu", 1).is_err());
    }

    #[test]
    fn from_str_specs_are_self_contained() {
        assert_eq!("native".parse::<EngineKind>().unwrap(), EngineKind::Native);
        assert_eq!("batch".parse::<EngineKind>().unwrap(), EngineKind::Batch);
        assert_eq!("batchf32".parse::<EngineKind>().unwrap(), EngineKind::BatchF32);
        assert_eq!("xla".parse::<EngineKind>().unwrap(), EngineKind::Xla);
        assert_eq!("strong:8".parse::<EngineKind>().unwrap(), EngineKind::Strong { threads: 8 });
        assert_eq!("strong:0".parse::<EngineKind>().unwrap(), EngineKind::Strong { threads: 1 });
        // bare `strong` defaults to the historical CLI width of 2
        assert_eq!("strong".parse::<EngineKind>().unwrap(), EngineKind::Strong { threads: 2 });
    }

    #[test]
    fn from_str_rejects_malformed_specs() {
        for bad in
            ["gpu", "strong:x", "strong:", "strong:4:2", "native:2", "batch:8", "batchf32:2", ""]
        {
            assert!(bad.parse::<EngineKind>().is_err(), "spec '{bad}' must be rejected");
        }
    }

    #[test]
    fn spec_round_trips_through_from_str() {
        for kind in EngineKind::all_tiers(8) {
            let spec = kind.spec();
            assert_eq!(spec.parse::<EngineKind>().unwrap(), kind, "spec '{spec}'");
        }
        assert_eq!(EngineKind::Strong { threads: 8 }.spec(), "strong:8");
    }

    #[test]
    fn legacy_parse_accepts_spec_form_and_prefers_it() {
        // a spec-form string through the old two-arg entry point wins
        // over the side-channel threads argument
        assert_eq!(
            EngineKind::parse("strong:8", 3).unwrap(),
            EngineKind::Strong { threads: 8 }
        );
        assert_eq!(EngineKind::parse("native", 0).unwrap(), EngineKind::Native);
    }

    #[test]
    fn batch_engine_exposes_phases() {
        let mut e = EngineKind::Batch.build(SortParams::default()).unwrap();
        e.update(&[Bbox::new(0.0, 0.0, 10.0, 20.0)]);
        let phases = e.phases().expect("batch collects phases");
        assert_eq!(phases.get(crate::sort::Phase::Predict).count, 1);
    }

    #[test]
    fn batchf32_engine_exposes_phases_and_its_own_name() {
        let mut e = EngineKind::BatchF32.build(SortParams::default()).unwrap();
        assert_eq!(e.name(), "batchf32");
        e.update(&[Bbox::new(0.0, 0.0, 10.0, 20.0)]);
        let phases = e.phases().expect("batchf32 collects phases");
        assert_eq!(phases.get(crate::sort::Phase::Predict).count, 1);
    }

    #[test]
    fn every_kind_builds_and_tracks() {
        let synth = generate_sequence(&SynthConfig::mot15("ENG", 40, 5, 3));
        for kind in EngineKind::all_tiers(2) {
            let mut e = kind.build(params()).expect("build");
            assert_eq!(e.name(), kind.label());
            let (frames, tracks) = run_sequence(&mut *e, &synth.sequence);
            assert_eq!(frames, 40, "{}", kind.label());
            assert!(tracks > 0, "{} produced no tracks", kind.label());
            assert!(e.n_trackers() > 0);
            e.reset();
            assert_eq!(e.n_trackers(), 0, "{} reset", kind.label());
        }
    }

    #[test]
    fn reset_restarts_ids() {
        let synth = generate_sequence(&SynthConfig::mot15("RST", 30, 4, 9));
        for kind in EngineKind::all_tiers(2) {
            let mut e = kind.build(params()).expect("build");
            let (_, first) = run_sequence(&mut *e, &synth.sequence);
            e.reset();
            let (_, second) = run_sequence(&mut *e, &synth.sequence);
            assert_eq!(first, second, "{}: reset must reproduce the run", kind.label());
        }
    }

    #[test]
    fn shared_runtime_builds_equivalent_bank_engines() {
        let rt = XlaRuntime::new().expect("runtime");
        let synth = generate_sequence(&SynthConfig::mot15("SHR", 30, 4, 7));
        let mut a = EngineKind::Xla.build_with_runtime(&rt, params()).expect("shared");
        let mut b = EngineKind::Xla.build(params()).expect("private");
        let ra = run_sequence(&mut *a, &synth.sequence);
        let rb = run_sequence(&mut *b, &synth.sequence);
        assert_eq!(ra, rb);
        // non-bank kinds accept (and ignore) the runtime
        let mut n = EngineKind::Native.build_with_runtime(&rt, params()).expect("native");
        assert_eq!(run_sequence(&mut *n, &synth.sequence), ra);
    }

    #[test]
    fn migration_between_f64_engines_is_bit_exact_mid_stream() {
        // run 25 frames on native, export at frame 25, import into
        // batch, continue both; the migrated run must stay
        // f64::to_bits-identical to the unmigrated one
        let synth = generate_sequence(&SynthConfig::mot15("MIG", 60, 6, 11));
        let mut reference = EngineKind::Native.build(params()).unwrap();
        let mut source = EngineKind::Native.build(params()).unwrap();
        let mut boxes: Vec<Bbox> = Vec::new();
        for frame in &synth.sequence.frames[..25] {
            boxes.clear();
            boxes.extend(frame.detections.iter().map(|d| d.bbox));
            reference.update(&boxes);
            source.update(&boxes);
        }
        let state = source.export_state().expect("native exports");
        let mut target = EngineKind::Batch.build(params()).unwrap();
        assert!(target.import_state(&state), "batch imports");
        assert_eq!(target.n_trackers(), reference.n_trackers());
        for frame in &synth.sequence.frames[25..] {
            boxes.clear();
            boxes.extend(frame.detections.iter().map(|d| d.bbox));
            let want = reference.update(&boxes).to_vec();
            let got = target.update(&boxes).to_vec();
            assert_eq!(want.len(), got.len(), "frame {}", frame.index);
            for (w, g) in want.iter().zip(&got) {
                assert_eq!(w.id, g.id, "frame {}", frame.index);
                assert_eq!(
                    w.bbox.to_array().map(f64::to_bits),
                    g.bbox.to_array().map(f64::to_bits),
                    "frame {} id {}",
                    frame.index,
                    w.id
                );
            }
        }
    }

    #[test]
    fn xla_bank_does_not_support_migration() {
        let mut e = EngineKind::Xla.build(params()).unwrap();
        e.update(&[Bbox::new(0.0, 0.0, 10.0, 20.0)]);
        assert!(e.export_state().is_none());
        assert!(!e.import_state(&EngineState::default()));
        assert_eq!(e.n_trackers(), 1, "failed import leaves state untouched");
    }

    #[test]
    fn f32_round_trip_through_f64_state_is_deterministic() {
        let synth = generate_sequence(&SynthConfig::mot15("M32", 40, 5, 13));
        let run = || {
            let mut e = EngineKind::Batch.build(params()).unwrap();
            let mut rows = Vec::new();
            let mut boxes: Vec<Bbox> = Vec::new();
            for (k, frame) in synth.sequence.frames.iter().enumerate() {
                boxes.clear();
                boxes.extend(frame.detections.iter().map(|d| d.bbox));
                if k == 15 {
                    let s = e.export_state().unwrap();
                    let mut f32e = EngineKind::BatchF32.build(params()).unwrap();
                    assert!(f32e.import_state(&s));
                    e = f32e;
                } else if k == 30 {
                    let s = e.export_state().unwrap();
                    let mut f64e = EngineKind::Batch.build(params()).unwrap();
                    assert!(f64e.import_state(&s));
                    e = f64e;
                }
                for t in e.update(&boxes) {
                    rows.push((t.id, t.bbox.to_array().map(f64::to_bits)));
                }
            }
            rows
        };
        assert_eq!(run(), run(), "batch→batchf32→batch must be run-to-run deterministic");
    }

    #[test]
    fn native_engine_exposes_phases() {
        let mut e = EngineKind::Native.build(SortParams::default()).unwrap();
        e.update(&[Bbox::new(0.0, 0.0, 10.0, 20.0)]);
        let phases = e.phases().expect("native collects phases");
        assert_eq!(phases.get(crate::sort::Phase::Predict).count, 1);
    }

    #[test]
    fn strong_engine_exposes_phases() {
        let mut e = EngineKind::Strong { threads: 2 }.build(SortParams::default()).unwrap();
        e.update(&[Bbox::new(0.0, 0.0, 10.0, 20.0)]);
        let phases = e.phases().expect("strong collects phases (incl. fork-join overhead)");
        assert_eq!(phases.get(crate::sort::Phase::Predict).count, 1);
    }
}
