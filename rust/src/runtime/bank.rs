//! The tracker bank: SORT with its dense algebra offloaded to the
//! batched bank kernels (AOT-compiled JAX/Pallas via PJRT, or the
//! built-in reference interpreter — see [`super::client`]).
//!
//! This is the accelerator-shaped variant of the tracker: state lives
//! in fixed `(T,7)` / `(T,7,7)` slot arrays; predict + IoU run as one
//! kernel call, association (control flow) stays in Rust, and the
//! matched updates run as a second kernel call. Lifecycle semantics are
//! identical to the native [`crate::sort::Sort`] — equivalence is
//! integration-tested in `rust/tests/integration_runtime.rs` and
//! `rust/tests/integration_engines.rs`.
//!
//! All marshalling buffers (padded detections, measurement rows, the
//! compressed IoU view, kernel outputs) are owned by the bank and
//! reused across frames: after warm-up the per-frame path performs no
//! heap allocation, the same invariant `Sort::update` holds.
//!
//! The per-call dispatch overhead vs. the native path at various bank
//! sizes is exactly the paper's "tiny matrices don't amortize"
//! argument, measured by `cargo bench --bench xla_vs_native` (E8).

use super::client::{Artifact, XlaRuntime};
use crate::sort::association::associate_from_matrix_into;
use crate::sort::FrameScratch;
use crate::sort::{AssociationMethod, Bbox, SortParams, Track};
use anyhow::Result;

const DX: usize = 7;
const DZ: usize = 4;

/// Padded tracker-slot arrays (the kernel-side state).
#[derive(Debug, Clone)]
pub struct BankState {
    /// Bank capacity (slot count `T`).
    pub t: usize,
    /// `(T,7)` row-major states.
    pub x: Vec<f64>,
    /// `(T,7,7)` row-major covariances.
    pub p: Vec<f64>,
    /// `(T,1)` live mask.
    pub mask: Vec<f64>,
}

impl BankState {
    /// Empty bank with `t` slots.
    pub fn new(t: usize) -> Self {
        BankState { t, x: vec![0.0; t * DX], p: vec![0.0; t * DX * DX], mask: vec![0.0; t] }
    }

    /// Indices of live slots.
    pub fn live_slots(&self) -> Vec<usize> {
        (0..self.t).filter(|&i| self.mask[i] > 0.0).collect()
    }

    /// First free slot.
    pub fn free_slot(&self) -> Option<usize> {
        (0..self.t).find(|&i| self.mask[i] == 0.0)
    }

    /// Seed slot `i` from measurement `z` (velocities 0, covariance P0).
    pub fn seed(&mut self, i: usize, z: &[f64; 4]) {
        let consts = crate::sort::SortConstants::sort_defaults();
        self.x[i * DX..i * DX + 4].copy_from_slice(z);
        self.x[i * DX + 4..(i + 1) * DX].fill(0.0);
        consts.p0.write_to(&mut self.p[i * DX * DX..(i + 1) * DX * DX]);
        self.mask[i] = 1.0;
    }

    /// Kill slot `i`.
    pub fn kill(&mut self, i: usize) {
        self.mask[i] = 0.0;
    }

    /// Clear every slot (stream reuse; buffers keep their capacity).
    pub fn clear(&mut self) {
        self.x.fill(0.0);
        self.p.fill(0.0);
        self.mask.fill(0.0);
    }
}

/// Per-slot lifecycle bookkeeping (the Rust-side tracker metadata).
#[derive(Debug, Clone, Copy, Default)]
struct SlotMeta {
    id: u64,
    time_since_update: u32,
    hit_streak: u32,
    hits: u32,
    age: u32,
}

/// SORT over the batched tracker bank — the `xla` engine.
pub struct TrackerBank {
    predict_iou: Artifact,
    update: Artifact,
    params: SortParams,
    bank: BankState,
    meta: Vec<SlotMeta>,
    /// Detection capacity `D` (padded).
    pub d_cap: usize,
    frame_count: u64,
    next_id: u64,
    assoc: FrameScratch,
    out: Vec<Track>,
    /// Detections ignored because they exceeded the padded capacity.
    pub overflow_dets: u64,
    /// One-shot warning latch: capacity overflow means the bank is
    /// silently dropping work and its output diverges from the native
    /// engine — surface that once, loudly, even through the trait.
    warned_overflow: bool,
    // --- reused marshalling buffers (no per-frame allocation) ---
    det_buf: Vec<f64>,
    dmask: Vec<f64>,
    z_buf: Vec<f64>,
    zmask_buf: Vec<f64>,
    iou_view: Vec<f64>,
    live: Vec<usize>,
    predict_outs: Vec<Vec<f64>>,
    update_outs: Vec<Vec<f64>>,
}

/// Former name of [`TrackerBank`], kept for source compatibility.
pub type XlaSortBank = TrackerBank;

impl TrackerBank {
    /// Build from a runtime (artifacts `bank_predict_iou` + `bank_update`).
    pub fn new(rt: &XlaRuntime, params: SortParams) -> Result<Self> {
        let predict_iou = rt.load("bank_predict_iou")?;
        let update = rt.load("bank_update")?;
        let t = predict_iou.input_shapes[0][0];
        let d_cap = predict_iou.input_shapes[3][0];
        Ok(TrackerBank {
            predict_iou,
            update,
            params,
            bank: BankState::new(t),
            meta: vec![SlotMeta::default(); t],
            d_cap,
            frame_count: 0,
            next_id: 0,
            assoc: FrameScratch::default(),
            out: Vec::new(),
            overflow_dets: 0,
            warned_overflow: false,
            det_buf: vec![0.0; d_cap * DZ],
            dmask: vec![0.0; d_cap],
            z_buf: vec![0.0; t * DZ],
            zmask_buf: vec![0.0; t],
            iou_view: Vec::with_capacity(d_cap * t),
            live: Vec::with_capacity(t),
            predict_outs: Vec::new(),
            update_outs: Vec::new(),
        })
    }

    /// Bank capacity.
    pub fn capacity(&self) -> usize {
        self.bank.t
    }

    /// Live tracker count.
    pub fn n_trackers(&self) -> usize {
        self.bank.mask.iter().filter(|&&m| m > 0.0).count()
    }

    /// Emit the capacity-overflow warning once per bank instance.
    /// Overflowed detections are dropped, so the bank's output is no
    /// longer equivalent to the native engine's; `overflow_dets` keeps
    /// the exact count for programmatic checks. Takes the fields it
    /// needs (not `&mut self`) so callers holding disjoint borrows of
    /// the association result can still warn.
    fn warn_overflow(warned: &mut bool, t: usize, d_cap: usize) {
        if !*warned {
            *warned = true;
            eprintln!(
                "smalltrack: tracker bank capacity exceeded (T={t}, D={d_cap}); dropping \
                 overflow detections — output diverges from the native engine \
                 (see TrackerBank::overflow_dets)"
            );
        }
    }

    /// Drop all tracker state (ids restart) but keep every warm buffer.
    pub fn reset(&mut self) {
        self.bank.clear();
        for m in &mut self.meta {
            *m = SlotMeta::default();
        }
        self.frame_count = 0;
        self.next_id = 0;
        self.overflow_dets = 0;
        self.out.clear();
    }

    /// Process one frame; same semantics as `Sort::update`, modulo the
    /// fixed capacity (`T` trackers, `D` detections; overflow counted).
    pub fn update(&mut self, dets: &[Bbox]) -> Result<&[Track]> {
        self.frame_count += 1;
        let t = self.bank.t;

        // --- pad detections into the reused buffers
        if dets.len() > self.d_cap {
            self.overflow_dets += (dets.len() - self.d_cap) as u64;
            Self::warn_overflow(&mut self.warned_overflow, self.bank.t, self.d_cap);
        }
        let nd = dets.len().min(self.d_cap);
        self.det_buf.fill(0.0);
        self.dmask.fill(0.0);
        for (i, b) in dets.iter().take(nd).enumerate() {
            self.det_buf[i * DZ..(i + 1) * DZ].copy_from_slice(&b.to_array());
            self.dmask[i] = 1.0;
        }

        // --- kernel call 1: predict + boxes + IoU matrix (D x T)
        self.predict_iou.run_into(
            &[
                &self.bank.x,
                &self.bank.p,
                &self.bank.mask,
                &self.det_buf,
                &self.dmask,
            ],
            &mut self.predict_outs,
        )?;
        self.bank.x.copy_from_slice(&self.predict_outs[0]);
        self.bank.p.copy_from_slice(&self.predict_outs[1]);

        // --- lifecycle: age/streak/tsu per live slot; cull non-finite
        // (the kernels zero non-finite boxes, so "all-zero" is the
        // corrupt-tracker signal here, mirroring Sort's NaN culling)
        {
            let boxes = &self.predict_outs[2];
            for i in 0..t {
                if self.bank.mask[i] == 0.0 {
                    continue;
                }
                let finite = boxes[i * 4..(i + 1) * 4].iter().all(|v| v.is_finite())
                    && boxes[i * 4..(i + 1) * 4].iter().any(|v| *v != 0.0);
                if !finite {
                    self.bank.kill(i);
                    continue;
                }
                let m = &mut self.meta[i];
                m.age += 1;
                if m.time_since_update > 0 {
                    m.hit_streak = 0;
                }
                m.time_since_update += 1;
            }
        }

        // --- association on the compressed (real dets × live slots) view
        let live = &mut self.live;
        live.clear();
        for (i, &m) in self.bank.mask.iter().enumerate() {
            if m > 0.0 {
                live.push(i);
            }
        }
        let nt = live.len();
        self.iou_view.clear();
        self.iou_view.resize(nd * nt, 0.0);
        {
            let iou_full = &self.predict_outs[3];
            for d in 0..nd {
                for (k, &slot) in live.iter().enumerate() {
                    self.iou_view[d * nt + k] = iou_full[d * t + slot];
                }
            }
        }
        associate_from_matrix_into(
            &self.iou_view,
            nd,
            nt,
            self.params.iou_threshold,
            self.params.method,
            &mut self.assoc,
        );

        // --- kernel call 2: masked measurement update for matched
        // slots (the association result is read in place from the
        // scratch — no per-frame clone of its vectors)
        if !self.assoc.result.matched.is_empty() {
            self.z_buf.fill(0.0);
            self.zmask_buf.fill(0.0);
            for &(d, k) in &self.assoc.result.matched {
                let slot = self.live[k];
                let zd = dets[d].to_z();
                self.z_buf[slot * DZ..(slot + 1) * DZ].copy_from_slice(&zd);
                self.zmask_buf[slot] = 1.0;
                let m = &mut self.meta[slot];
                m.time_since_update = 0;
                m.hits += 1;
                m.hit_streak += 1;
            }
            self.update.run_into(
                &[&self.bank.x, &self.bank.p, &self.z_buf, &self.zmask_buf],
                &mut self.update_outs,
            )?;
            self.bank.x.copy_from_slice(&self.update_outs[0]);
            self.bank.p.copy_from_slice(&self.update_outs[1]);
        }

        // --- create new trackers from unmatched detections
        for &d in &self.assoc.result.unmatched_dets {
            let Some(slot) = self.bank.free_slot() else {
                self.overflow_dets += 1;
                Self::warn_overflow(&mut self.warned_overflow, self.bank.t, self.d_cap);
                continue;
            };
            self.bank.seed(slot, &dets[d].to_z());
            self.meta[slot] = SlotMeta { id: self.next_id, ..Default::default() };
            self.next_id += 1;
        }

        // --- output + cull (slot order ≈ tracker order)
        self.out.clear();
        for i in 0..t {
            if self.bank.mask[i] == 0.0 {
                continue;
            }
            let m = self.meta[i];
            if m.time_since_update < 1
                && (m.hit_streak >= self.params.min_hits
                    || self.frame_count <= self.params.min_hits as u64)
            {
                let xi: &[f64] = &self.bank.x[i * DX..(i + 1) * DX];
                let state: [f64; 7] = xi.try_into().unwrap();
                self.out.push(Track { id: m.id + 1, bbox: Bbox::from_state(&state) });
            }
            if m.time_since_update > self.params.max_age {
                self.bank.kill(i);
            }
        }
        self.out.sort_by(|a, b| b.id.cmp(&a.id)); // match Sort's reverse-order output
        Ok(&self.out)
    }
}

/// Association method re-export for bank users.
pub fn default_method() -> AssociationMethod {
    AssociationMethod::Hungarian
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_state_slot_management() {
        let mut b = BankState::new(4);
        assert_eq!(b.live_slots().len(), 0);
        assert_eq!(b.free_slot(), Some(0));
        b.seed(0, &[10.0, 20.0, 400.0, 0.5]);
        b.seed(2, &[1.0, 2.0, 100.0, 1.0]);
        assert_eq!(b.live_slots(), vec![0, 2]);
        assert_eq!(b.free_slot(), Some(1));
        assert_eq!(b.x[0], 10.0);
        assert_eq!(b.x[4], 0.0); // velocity zeroed
        // P0 diagonal
        assert_eq!(b.p[0], 10.0);
        assert_eq!(b.p[4 * 7 + 4], 10000.0);
        b.kill(0);
        assert_eq!(b.live_slots(), vec![2]);
    }

    #[test]
    fn seed_overwrites_previous_state() {
        let mut b = BankState::new(2);
        b.seed(1, &[1.0, 1.0, 1.0, 1.0]);
        b.kill(1);
        b.seed(1, &[9.0, 9.0, 9.0, 9.0]);
        assert_eq!(b.x[7], 9.0);
        assert_eq!(b.mask[1], 1.0);
    }

    #[test]
    fn bank_tracks_a_moving_object() {
        let rt = XlaRuntime::new().expect("runtime");
        let mut bank = TrackerBank::new(&rt, SortParams { timing: false, ..Default::default() })
            .expect("bank");
        assert_eq!(bank.capacity(), 16);
        let b = |k: f64| Bbox::new(10.0 + 2.0 * k, 10.0, 40.0 + 2.0 * k, 80.0);
        for k in 0..6 {
            bank.update(&[b(k as f64)]).unwrap();
        }
        assert_eq!(bank.n_trackers(), 1);
        let tracks = bank.update(&[b(6.0)]).unwrap();
        assert_eq!(tracks.len(), 1);
        assert_eq!(tracks[0].id, 1);
        // coast past max_age: culled
        bank.update(&[]).unwrap();
        bank.update(&[]).unwrap();
        assert_eq!(bank.n_trackers(), 0);
    }

    #[test]
    fn reset_restarts_ids_and_state() {
        let rt = XlaRuntime::new().expect("runtime");
        let mut bank = TrackerBank::new(&rt, SortParams { timing: false, ..Default::default() })
            .expect("bank");
        let b = Bbox::new(5.0, 5.0, 50.0, 90.0);
        for _ in 0..4 {
            bank.update(&[b]).unwrap();
        }
        assert_eq!(bank.n_trackers(), 1);
        bank.reset();
        assert_eq!(bank.n_trackers(), 0);
        let tracks = bank.update(&[b]).unwrap().to_vec();
        assert_eq!(tracks.len(), 1);
        assert_eq!(tracks[0].id, 1, "ids restart after reset");
    }
}
