//! The XLA tracker bank: SORT with its dense algebra offloaded to the
//! AOT-compiled JAX/Pallas kernels.
//!
//! This is the accelerator-shaped variant of the tracker (DESIGN.md
//! §Hardware-Adaptation): tracker state lives in fixed `(T,7)` /
//! `(T,7,7)` slot arrays; predict + IoU run as one compiled XLA call,
//! association (control flow) stays in Rust, and the matched updates
//! run as a second XLA call. Lifecycle semantics are identical to the
//! native [`crate::sort::Sort`] — equivalence is integration-tested in
//! `rust/tests/integration_runtime.rs`.
//!
//! The per-call dispatch overhead vs. the native path at various bank
//! sizes is exactly the paper's "tiny matrices don't amortize"
//! argument, measured by `cargo bench --bench xla_vs_native` (E8).

use super::client::{Artifact, XlaRuntime};
use crate::sort::association::{associate_from_matrix, AssociationScratch};
use crate::sort::{AssociationMethod, Bbox, SortParams, Track};
use anyhow::Result;

const DX: usize = 7;
const DZ: usize = 4;

/// Padded tracker-slot arrays (the XLA-side state).
#[derive(Debug, Clone)]
pub struct BankState {
    /// Bank capacity (slot count `T`).
    pub t: usize,
    /// `(T,7)` row-major states.
    pub x: Vec<f64>,
    /// `(T,7,7)` row-major covariances.
    pub p: Vec<f64>,
    /// `(T,1)` live mask.
    pub mask: Vec<f64>,
}

impl BankState {
    /// Empty bank with `t` slots.
    pub fn new(t: usize) -> Self {
        BankState { t, x: vec![0.0; t * DX], p: vec![0.0; t * DX * DX], mask: vec![0.0; t] }
    }

    /// Indices of live slots.
    pub fn live_slots(&self) -> Vec<usize> {
        (0..self.t).filter(|&i| self.mask[i] > 0.0).collect()
    }

    /// First free slot.
    pub fn free_slot(&self) -> Option<usize> {
        (0..self.t).find(|&i| self.mask[i] == 0.0)
    }

    /// Seed slot `i` from measurement `z` (velocities 0, covariance P0).
    pub fn seed(&mut self, i: usize, z: &[f64; 4]) {
        let consts = crate::sort::SortConstants::sort_defaults();
        self.x[i * DX..i * DX + 4].copy_from_slice(z);
        self.x[i * DX + 4..(i + 1) * DX].fill(0.0);
        for r in 0..DX {
            for c in 0..DX {
                self.p[i * DX * DX + r * DX + c] = consts.p0[(r, c)];
            }
        }
        self.mask[i] = 1.0;
    }

    /// Kill slot `i`.
    pub fn kill(&mut self, i: usize) {
        self.mask[i] = 0.0;
    }
}

/// Per-slot lifecycle bookkeeping (the Rust-side tracker metadata).
#[derive(Debug, Clone, Copy, Default)]
struct SlotMeta {
    id: u64,
    time_since_update: u32,
    hit_streak: u32,
    hits: u32,
    age: u32,
}

/// SORT over the XLA tracker bank.
pub struct XlaSortBank {
    predict_iou: Artifact,
    update: Artifact,
    params: SortParams,
    bank: BankState,
    meta: Vec<SlotMeta>,
    /// Detection capacity `D` (padded).
    pub d_cap: usize,
    frame_count: u64,
    next_id: u64,
    assoc: AssociationScratch,
    out: Vec<Track>,
    /// Detections ignored because they exceeded the padded capacity.
    pub overflow_dets: u64,
}

impl XlaSortBank {
    /// Build from a runtime (artifacts `bank_predict_iou` + `bank_update`).
    pub fn new(rt: &XlaRuntime, params: SortParams) -> Result<Self> {
        let predict_iou = rt.load("bank_predict_iou")?;
        let update = rt.load("bank_update")?;
        let t = predict_iou.input_shapes[0][0];
        let d_cap = predict_iou.input_shapes[3][0];
        Ok(XlaSortBank {
            predict_iou,
            update,
            params,
            bank: BankState::new(t),
            meta: vec![SlotMeta::default(); t],
            d_cap,
            frame_count: 0,
            next_id: 0,
            assoc: AssociationScratch::default(),
            out: Vec::new(),
            overflow_dets: 0,
        })
    }

    /// Bank capacity.
    pub fn capacity(&self) -> usize {
        self.bank.t
    }

    /// Live tracker count.
    pub fn n_trackers(&self) -> usize {
        self.bank.live_slots().len()
    }

    /// Process one frame; same semantics as `Sort::update`, modulo the
    /// fixed capacity (`T` trackers, `D` detections; overflow counted).
    pub fn update(&mut self, dets: &[Bbox]) -> Result<&[Track]> {
        self.frame_count += 1;
        let t = self.bank.t;

        // --- pad detections
        if dets.len() > self.d_cap {
            self.overflow_dets += (dets.len() - self.d_cap) as u64;
        }
        let nd = dets.len().min(self.d_cap);
        let mut det_buf = vec![0.0; self.d_cap * DZ];
        let mut dmask = vec![0.0; self.d_cap];
        for (i, b) in dets.iter().take(nd).enumerate() {
            det_buf[i * DZ..(i + 1) * DZ].copy_from_slice(&b.to_array());
            dmask[i] = 1.0;
        }

        // --- XLA call 1: predict + boxes + IoU matrix (D x T)
        let outs = self.predict_iou.run(&[
            &self.bank.x,
            &self.bank.p,
            &self.bank.mask,
            &det_buf,
            &dmask,
        ])?;
        let (xn, pn, boxes, iou_full) = (&outs[0], &outs[1], &outs[2], &outs[3]);
        self.bank.x.copy_from_slice(xn);
        self.bank.p.copy_from_slice(pn);

        // --- lifecycle: age/streak/tsu per live slot; cull non-finite
        for i in 0..t {
            if self.bank.mask[i] == 0.0 {
                continue;
            }
            let finite = boxes[i * 4..(i + 1) * 4].iter().all(|v| v.is_finite())
                && boxes[i * 4..(i + 1) * 4].iter().any(|v| *v != 0.0);
            if !finite {
                self.bank.kill(i);
                continue;
            }
            let m = &mut self.meta[i];
            m.age += 1;
            if m.time_since_update > 0 {
                m.hit_streak = 0;
            }
            m.time_since_update += 1;
        }

        // --- association on the compressed (real dets × live slots) view
        let live = self.bank.live_slots();
        let nt = live.len();
        let mut iou = vec![0.0; nd * nt];
        for d in 0..nd {
            for (k, &slot) in live.iter().enumerate() {
                iou[d * nt + k] = iou_full[d * t + slot];
            }
        }
        let result = associate_from_matrix(
            &iou,
            nd,
            nt,
            self.params.iou_threshold,
            self.params.method,
            &mut self.assoc,
        );

        // --- XLA call 2: masked measurement update for matched slots
        if !result.matched.is_empty() {
            let mut z = vec![0.0; t * DZ];
            let mut zmask = vec![0.0; t];
            for &(d, k) in &result.matched {
                let slot = live[k];
                let zd = dets[d].to_z();
                z[slot * DZ..(slot + 1) * DZ].copy_from_slice(&zd);
                zmask[slot] = 1.0;
                let m = &mut self.meta[slot];
                m.time_since_update = 0;
                m.hits += 1;
                m.hit_streak += 1;
            }
            let outs = self.update.run(&[&self.bank.x, &self.bank.p, &z, &zmask])?;
            self.bank.x.copy_from_slice(&outs[0]);
            self.bank.p.copy_from_slice(&outs[1]);
        }

        // --- create new trackers from unmatched detections
        for &d in &result.unmatched_dets {
            let Some(slot) = self.bank.free_slot() else {
                self.overflow_dets += 1;
                continue;
            };
            self.bank.seed(slot, &dets[d].to_z());
            self.meta[slot] = SlotMeta { id: self.next_id, ..Default::default() };
            self.next_id += 1;
        }

        // --- output + cull (slot order ≈ tracker order)
        self.out.clear();
        for i in 0..t {
            if self.bank.mask[i] == 0.0 {
                continue;
            }
            let m = self.meta[i];
            if m.time_since_update < 1
                && (m.hit_streak >= self.params.min_hits
                    || self.frame_count <= self.params.min_hits as u64)
            {
                let xi: &[f64] = &self.bank.x[i * DX..(i + 1) * DX];
                let state: [f64; 7] = xi.try_into().unwrap();
                self.out.push(Track { id: m.id + 1, bbox: Bbox::from_state(&state) });
            }
            if m.time_since_update > self.params.max_age {
                self.bank.kill(i);
            }
        }
        self.out.sort_by(|a, b| b.id.cmp(&a.id)); // match Sort's reverse-order output
        Ok(&self.out)
    }
}

/// Association method re-export for bank users.
pub fn default_method() -> AssociationMethod {
    AssociationMethod::Hungarian
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_state_slot_management() {
        let mut b = BankState::new(4);
        assert_eq!(b.live_slots().len(), 0);
        assert_eq!(b.free_slot(), Some(0));
        b.seed(0, &[10.0, 20.0, 400.0, 0.5]);
        b.seed(2, &[1.0, 2.0, 100.0, 1.0]);
        assert_eq!(b.live_slots(), vec![0, 2]);
        assert_eq!(b.free_slot(), Some(1));
        assert_eq!(b.x[0], 10.0);
        assert_eq!(b.x[4], 0.0); // velocity zeroed
        // P0 diagonal
        assert_eq!(b.p[0], 10.0);
        assert_eq!(b.p[4 * 7 + 4], 10000.0);
        b.kill(0);
        assert_eq!(b.live_slots(), vec![2]);
    }

    #[test]
    fn seed_overwrites_previous_state() {
        let mut b = BankState::new(2);
        b.seed(1, &[1.0, 1.0, 1.0, 1.0]);
        b.kill(1);
        b.seed(1, &[9.0, 9.0, 9.0, 9.0]);
        assert_eq!(b.x[7], 9.0);
        assert_eq!(b.mask[1], 1.0);
    }
}
