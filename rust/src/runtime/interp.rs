//! Reference interpreter for the tracker-bank kernels.
//!
//! The AOT pipeline (`python/compile/model.py`) lowers exactly three
//! kernel families to HLO: `bank_predict_iou`, `bank_update`, and the
//! `bank_predict_T{n}` sweep. Their semantics are fully specified by
//! the jnp oracle (`python/compile/kernels/ref.py`); this module
//! implements the same batched contracts in pure Rust so the bank
//! engine runs — and is testable — on machines without the PJRT
//! execution backend (the `pjrt` cargo feature).
//!
//! Numerically the interpreter reuses the *native* structure-aware
//! Kalman kernels ([`KalmanState::predict`] / [`KalmanState::update`]),
//! so the bank engine's per-slot state evolves bit-identically to
//! [`crate::sort::Sort`]'s — which is what makes the
//! `--engine native` vs `--engine xla` byte-parity guarantee (and
//! `rust/tests/integration_engines.rs`) possible. The real XLA
//! artifacts use the dense formulation instead; the two agree to ~1e-9
//! (unit-tested in `rust/src/sort/kalman.rs`), within every consumer's
//! tolerance.
//!
//! All entry points write into caller-provided output buffers — the
//! per-frame path performs no heap allocation after warm-up, preserving
//! `Sort::update`'s invariant on the bank path.

use crate::linalg::{Mat7, Vec4, Vec7};
use crate::sort::iou::iou_raw;
use crate::sort::kalman::{CovarianceForm, KalmanState, SortConstants};
use crate::sort::Bbox;
use anyhow::{ensure, Result};

const DX: usize = 7;
const DZ: usize = 4;

/// One interpretable kernel, with its bank geometry baked in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefKernel {
    /// `bank_predict_iou`: predict `T` slots, emit boxes + `(D,T)` IoU.
    PredictIou {
        /// Tracker-slot capacity.
        t: usize,
        /// Detection capacity.
        d: usize,
    },
    /// `bank_update`: masked Joseph-form measurement update of `T` slots.
    Update {
        /// Tracker-slot capacity.
        t: usize,
    },
    /// `bank_predict_T{n}`: bare masked predict (the E8 sweep unit).
    Predict {
        /// Tracker-slot capacity.
        t: usize,
    },
}

impl RefKernel {
    /// Resolve an artifact name to a kernel, using built-in default
    /// geometry (`T = D = 16`, matching `model.py`'s `BANK_T/BANK_D`).
    pub fn from_name(name: &str) -> Option<RefKernel> {
        match name {
            "bank_predict_iou" => Some(RefKernel::PredictIou { t: 16, d: 16 }),
            "bank_update" => Some(RefKernel::Update { t: 16 }),
            _ => name
                .strip_prefix("bank_predict_T")
                .and_then(|n| n.parse::<usize>().ok())
                .filter(|&t| t > 0)
                .map(|t| RefKernel::Predict { t }),
        }
    }

    /// Resolve a manifest entry (name + input shapes) to a kernel with
    /// the manifest's geometry.
    pub fn from_shapes(name: &str, input_shapes: &[Vec<usize>]) -> Option<RefKernel> {
        let t = *input_shapes.first()?.first()?;
        if name == "bank_predict_iou" {
            let d = *input_shapes.get(3)?.first()?;
            Some(RefKernel::PredictIou { t, d })
        } else if name == "bank_update" {
            Some(RefKernel::Update { t })
        } else if name.starts_with("bank_predict_T") {
            Some(RefKernel::Predict { t })
        } else {
            None
        }
    }

    /// Input shapes in argument order (row-major dims).
    pub fn input_shapes(&self) -> Vec<Vec<usize>> {
        match *self {
            RefKernel::PredictIou { t, d } => vec![
                vec![t, DX],
                vec![t, DX, DX],
                vec![t, 1],
                vec![d, DZ],
                vec![d, 1],
            ],
            RefKernel::Update { t } => {
                vec![vec![t, DX], vec![t, DX, DX], vec![t, DZ], vec![t, 1]]
            }
            RefKernel::Predict { t } => vec![vec![t, DX], vec![t, DX, DX], vec![t, 1]],
        }
    }

    /// Output shapes in tuple order.
    pub fn output_shapes(&self) -> Vec<Vec<usize>> {
        match *self {
            RefKernel::PredictIou { t, d } => vec![
                vec![t, DX],
                vec![t, DX, DX],
                vec![t, DZ],
                vec![d, t],
            ],
            RefKernel::Update { t } => vec![vec![t, DX], vec![t, DX, DX]],
            RefKernel::Predict { t } => vec![vec![t, DX], vec![t, DX, DX]],
        }
    }

    /// Execute into caller-provided output buffers (resized to the
    /// output shapes on first use, reused afterwards).
    pub fn run_into(&self, inputs: &[&[f64]], outs: &mut Vec<Vec<f64>>) -> Result<()> {
        let out_shapes = self.output_shapes();
        outs.resize(out_shapes.len(), Vec::new());
        for (o, shape) in outs.iter_mut().zip(&out_shapes) {
            o.resize(shape.iter().product(), 0.0);
        }
        let consts = SortConstants::sort_defaults();
        match *self {
            RefKernel::Predict { t } => {
                let (x, p, mask) = (inputs[0], inputs[1], inputs[2]);
                let (xn, rest) = outs.split_at_mut(1);
                predict_bank(t, x, p, mask, &consts, &mut xn[0], &mut rest[0]);
            }
            RefKernel::PredictIou { t, d } => {
                let (x, p, mask, dets, dmask) =
                    (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
                let (xn, rest) = outs.split_at_mut(1);
                let (pn, rest) = rest.split_at_mut(1);
                let (boxes, iou) = rest.split_at_mut(1);
                predict_bank(t, x, p, mask, &consts, &mut xn[0], &mut pn[0]);
                // boxes: x_to_bbox(xn) * mask, non-finite -> 0 (ref.py)
                let boxes = &mut boxes[0];
                for i in 0..t {
                    if mask[i] > 0.0 {
                        let xi: Vec7 = slice7(&xn[0], i);
                        let b = Bbox::from_state(&xi).to_array();
                        for (k, v) in b.iter().enumerate() {
                            boxes[i * DZ + k] = if v.is_finite() { *v } else { 0.0 };
                        }
                    } else {
                        boxes[i * DZ..(i + 1) * DZ].fill(0.0);
                    }
                }
                // iou (D,T), zeroed on padded/dead pairs
                let iou = &mut iou[0];
                for di in 0..d {
                    let db = Bbox::new(
                        dets[di * DZ],
                        dets[di * DZ + 1],
                        dets[di * DZ + 2],
                        dets[di * DZ + 3],
                    );
                    for ti in 0..t {
                        let tb = Bbox::new(
                            boxes[ti * DZ],
                            boxes[ti * DZ + 1],
                            boxes[ti * DZ + 2],
                            boxes[ti * DZ + 3],
                        );
                        iou[di * t + ti] = iou_raw(&db, &tb) * dmask[di] * mask[ti];
                    }
                }
            }
            RefKernel::Update { t } => {
                let (x, p, z, zmask) = (inputs[0], inputs[1], inputs[2], inputs[3]);
                let (xn, pn) = outs.split_at_mut(1);
                let (xn, pn) = (&mut xn[0], &mut pn[0]);
                xn.copy_from_slice(x);
                pn.copy_from_slice(p);
                for i in 0..t {
                    if zmask[i] <= 0.0 {
                        continue;
                    }
                    let mut ks = KalmanState {
                        x: slice7(xn, i),
                        p: Mat7::from_slice(&pn[i * DX * DX..(i + 1) * DX * DX]),
                    };
                    let zi: Vec4 = [
                        z[i * DZ],
                        z[i * DZ + 1],
                        z[i * DZ + 2],
                        z[i * DZ + 3],
                    ];
                    // Non-SPD innovation covariance: pass the slot
                    // through untouched (the compiled kernel computes a
                    // garbage inverse there; callers only feed live,
                    // well-conditioned slots, so the paths agree on all
                    // real inputs and the interpreter fails safer).
                    if ks.update(&zi, &consts, CovarianceForm::Joseph) {
                        xn[i * DX..(i + 1) * DX].copy_from_slice(&ks.x);
                        ks.p.write_to(&mut pn[i * DX * DX..(i + 1) * DX * DX]);
                    }
                }
            }
        }
        ensure!(outs.len() == out_shapes.len(), "interpreter output arity");
        Ok(())
    }
}

fn slice7(buf: &[f64], i: usize) -> Vec7 {
    let mut out = [0.0; DX];
    out.copy_from_slice(&buf[i * DX..(i + 1) * DX]);
    out
}

/// Masked batched predict: live slots advance with the structure-aware
/// kernel, dead slots pass through (ref.py's `predict_ref`).
fn predict_bank(
    t: usize,
    x: &[f64],
    p: &[f64],
    mask: &[f64],
    consts: &SortConstants,
    xn: &mut [f64],
    pn: &mut [f64],
) {
    xn.copy_from_slice(x);
    pn.copy_from_slice(p);
    for i in 0..t {
        if mask[i] <= 0.0 {
            continue;
        }
        let mut ks = KalmanState {
            x: slice7(xn, i),
            p: Mat7::from_slice(&pn[i * DX * DX..(i + 1) * DX * DX]),
        };
        ks.predict(consts);
        xn[i * DX..(i + 1) * DX].copy_from_slice(&ks.x);
        ks.p.write_to(&mut pn[i * DX * DX..(i + 1) * DX * DX]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_resolution() {
        assert_eq!(
            RefKernel::from_name("bank_predict_iou"),
            Some(RefKernel::PredictIou { t: 16, d: 16 })
        );
        assert_eq!(RefKernel::from_name("bank_update"), Some(RefKernel::Update { t: 16 }));
        assert_eq!(RefKernel::from_name("bank_predict_T64"), Some(RefKernel::Predict { t: 64 }));
        assert_eq!(RefKernel::from_name("bank_predict_T0"), None);
        assert_eq!(RefKernel::from_name("unknown"), None);
    }

    #[test]
    fn predict_matches_native_kalman_bitwise() {
        let consts = SortConstants::sort_defaults();
        let mut native = KalmanState::from_measurement(&[100.0, 50.0, 2000.0, 0.5], &consts);
        native.x[4] = 3.0;

        let k = RefKernel::Predict { t: 2 };
        let mut x = vec![0.0; 2 * 7];
        let mut p = vec![0.0; 2 * 49];
        x[..7].copy_from_slice(&native.x);
        native.p.write_to(&mut p[..49]);
        let mask = vec![1.0, 0.0];
        let mut outs = Vec::new();
        k.run_into(&[&x, &p, &mask], &mut outs).unwrap();

        native.predict(&consts);
        for i in 0..7 {
            assert_eq!(outs[0][i], native.x[i], "x[{i}] must be bit-identical");
        }
        for i in 0..49 {
            assert_eq!(outs[1][i], native.p[(i / 7, i % 7)], "p[{i}]");
        }
        // dead slot untouched
        assert!(outs[0][7..].iter().all(|v| *v == 0.0));
    }

    #[test]
    fn update_matches_native_kalman_bitwise() {
        let consts = SortConstants::sort_defaults();
        let mut native = KalmanState::from_measurement(&[200.0, 100.0, 3000.0, 0.6], &consts);
        native.predict(&consts);

        let k = RefKernel::Update { t: 1 };
        let mut x = vec![0.0; 7];
        let mut p = vec![0.0; 49];
        x.copy_from_slice(&native.x);
        native.p.write_to(&mut p);
        let z = vec![202.0, 99.0, 3050.0, 0.6];
        let zmask = vec![1.0];
        let mut outs = Vec::new();
        k.run_into(&[&x, &p, &z, &zmask], &mut outs).unwrap();

        assert!(native.update(&[202.0, 99.0, 3050.0, 0.6], &consts, CovarianceForm::Joseph));
        for i in 0..7 {
            assert_eq!(outs[0][i], native.x[i], "x[{i}]");
        }
    }

    #[test]
    fn predict_iou_masks_dead_and_padded_pairs() {
        let k = RefKernel::PredictIou { t: 2, d: 2 };
        let consts = SortConstants::sort_defaults();
        let seed = KalmanState::from_measurement(
            &Bbox::new(10.0, 10.0, 30.0, 50.0).to_z(),
            &consts,
        );
        let mut x = vec![0.0; 2 * 7];
        let mut p = vec![0.0; 2 * 49];
        x[..7].copy_from_slice(&seed.x);
        seed.p.write_to(&mut p[..49]);
        let mask = vec![1.0, 0.0];
        // det 0 = on top of the tracker; det 1 = padded row
        let dets = vec![10.0, 10.0, 30.0, 50.0, 999.0, 999.0, 1000.0, 1000.0];
        let dmask = vec![1.0, 0.0];
        let mut outs = Vec::new();
        k.run_into(&[&x, &p, &mask, &dets, &dmask], &mut outs).unwrap();

        let iou = &outs[3]; // (D=2, T=2)
        assert!(iou[0] > 0.9, "live pair overlaps: {}", iou[0]);
        assert_eq!(iou[1], 0.0, "dead slot column zeroed");
        assert_eq!(iou[2], 0.0, "padded det row zeroed");
        assert_eq!(iou[3], 0.0);
        // dead slot's box row is zero
        assert!(outs[2][4..8].iter().all(|v| *v == 0.0));
    }

    #[test]
    fn run_into_reuses_buffers_without_reallocation() {
        let k = RefKernel::Predict { t: 4 };
        let x = vec![1.0; 4 * 7];
        let p = vec![0.5; 4 * 49];
        let mask = vec![1.0; 4];
        let mut outs = Vec::new();
        k.run_into(&[&x, &p, &mask], &mut outs).unwrap();
        let caps: Vec<usize> = outs.iter().map(Vec::capacity).collect();
        let ptrs: Vec<*const f64> = outs.iter().map(|o| o.as_ptr()).collect();
        k.run_into(&[&x, &p, &mask], &mut outs).unwrap();
        assert_eq!(caps, outs.iter().map(Vec::capacity).collect::<Vec<_>>());
        assert_eq!(ptrs, outs.iter().map(|o| o.as_ptr()).collect::<Vec<_>>());
    }
}
