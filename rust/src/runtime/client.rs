//! PJRT client wrapper: compile `artifacts/*.hlo.txt` once, execute
//! many times.
//!
//! Follows the reference wiring of `/opt/xla-example/load_hlo`: text →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `compile` on
//! the CPU PJRT client. Inputs/outputs are `f64` literals (the paper's
//! doubles); jax lowers with `return_tuple=True`, so results unpack via
//! `to_tuple`.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Directory holding the AOT artifacts (`SMALLTRACK_ARTIFACTS` env
/// override; defaults to `./artifacts`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("SMALLTRACK_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Whether the AOT artifacts exist (runtime-dependent tests/benches
/// skip gracefully when `make artifacts` has not run).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// One compiled executable plus its I/O geometry.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name (manifest key).
    pub name: String,
    /// Input shapes (row-major dims) in argument order.
    pub input_shapes: Vec<Vec<usize>>,
    /// Output shapes in tuple order.
    pub output_shapes: Vec<Vec<usize>>,
}

impl Artifact {
    /// Execute on f64 row-major buffers (one per input, shapes as in
    /// `input_shapes`). Returns one row-major `Vec<f64>` per output.
    pub fn run(&self, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        anyhow::ensure!(
            inputs.len() == self.input_shapes.len(),
            "{}: expected {} inputs, got {}",
            self.name,
            self.input_shapes.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&self.input_shapes) {
            let n: usize = shape.iter().product();
            anyhow::ensure!(
                buf.len() == n,
                "{}: input length {} != shape {:?}",
                self.name,
                buf.len(),
                shape
            );
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape {:?}: {e:?}", shape))?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {}: {e:?}", self.name))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == self.output_shapes.len(),
            "{}: expected {} outputs, got {}",
            self.name,
            self.output_shapes.len(),
            parts.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        Ok(out)
    }
}

/// The PJRT client with every artifact from the manifest compiled.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: crate::data::json::Value,
}

impl XlaRuntime {
    /// CPU client over the default artifacts directory.
    pub fn new() -> Result<Self> {
        Self::with_dir(&artifacts_dir())
    }

    /// CPU client over an explicit artifacts directory.
    pub fn with_dir(dir: &Path) -> Result<Self> {
        let manifest = crate::data::json::parse_file(&dir.join("manifest.json"))
            .context("read manifest.json (run `make artifacts`)")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(XlaRuntime { client, dir: dir.to_path_buf(), manifest })
    }

    /// PJRT platform name ("Host" for CPU).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact names available in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        match self.manifest.req("artifacts") {
            crate::data::json::Value::Obj(m) => m.keys().cloned().collect(),
            _ => Vec::new(),
        }
    }

    /// Load + compile one artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Artifact> {
        let entry = self
            .manifest
            .req("artifacts")
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let file = entry.req("file").str().to_string();
        let path = self.dir.join(&file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;

        let shapes = |key: &str| -> Vec<Vec<usize>> {
            entry
                .req(key)
                .arr()
                .iter()
                .map(|io| io.arr()[1].arr().iter().map(|d| d.num() as usize).collect())
                .collect()
        };
        Ok(Artifact {
            exe,
            name: name.to_string(),
            input_shapes: shapes("inputs"),
            output_shapes: shapes("outputs"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full execution tests live in rust/tests/integration_runtime.rs
    // (they need `make artifacts`). Here: path/manifest plumbing only.

    #[test]
    fn artifacts_dir_env_override() {
        let dir = artifacts_dir();
        assert!(dir.ends_with("artifacts") || std::env::var_os("SMALLTRACK_ARTIFACTS").is_some());
    }

    #[test]
    fn with_dir_missing_manifest_errors() {
        let err = match XlaRuntime::with_dir(Path::new("/nonexistent-dir-xyz")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest"), "{msg}");
    }
}
