//! Artifact loading and kernel execution for the tracker bank.
//!
//! `make artifacts` lowers the L2 JAX graphs to HLO text plus a
//! `manifest.json` carrying each artifact's I/O geometry. This module
//! resolves artifact names to executable kernels behind one `Artifact`
//! handle, over pluggable execution backends:
//!
//! * **Reference interpreter** (always available) — the pure-Rust
//!   implementation of the bank kernel contracts in
//!   [`super::interp`]. Used whenever the compiled backend is absent;
//!   also works with *no* artifacts directory at all (built-in default
//!   geometry, `T = D = 16`), so `--engine xla` and the runtime tests
//!   run from a fresh clone.
//! * **PJRT** (cargo feature `pjrt`, not compiled here) — the original
//!   wiring compiles the HLO text on the PJRT CPU client via the
//!   `xla` crate (`HloModuleProto::from_text_file` → `XlaComputation`
//!   → `compile`, executing f64 literals with `return_tuple=True`
//!   unpacking). The offline build environment cannot vendor that
//!   crate, so the backend is gated out; re-enabling it means adding
//!   the dependency and a `Compiled` arm to [`ExecBackend`].
//!
//! Either way the calling code ([`super::bank`], benches, tests) sees
//! the same `Artifact::run` / `run_into` contract.

use super::interp::RefKernel;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Directory holding the AOT artifacts (`SMALLTRACK_ARTIFACTS` env
/// override; defaults to `./artifacts`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("SMALLTRACK_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Whether the full AOT artifact set exists (compiled-kernel benches
/// skip their HLO-specific sections when `make artifacts` has not run;
/// the reference interpreter does not need it).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Execution backend behind an [`Artifact`].
enum ExecBackend {
    /// Pure-Rust interpreter of the bank kernel contracts.
    Reference(RefKernel),
    // Compiled(xla::PjRtLoadedExecutable) lives behind the `pjrt`
    // feature once the xla crate is vendored; see module docs.
}

/// One executable kernel plus its I/O geometry.
pub struct Artifact {
    backend: ExecBackend,
    /// Artifact name (manifest key).
    pub name: String,
    /// Input shapes (row-major dims) in argument order.
    pub input_shapes: Vec<Vec<usize>>,
    /// Output shapes in tuple order.
    pub output_shapes: Vec<Vec<usize>>,
}

impl Artifact {
    /// Execute on f64 row-major buffers (one per input, shapes as in
    /// `input_shapes`). Returns one row-major `Vec<f64>` per output.
    ///
    /// Allocates the output vectors; per-frame callers use
    /// [`Self::run_into`] with reused buffers.
    pub fn run(&self, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        let mut outs = Vec::new();
        self.run_into(inputs, &mut outs)?;
        Ok(outs)
    }

    /// Execute into caller-provided output buffers: `outs` is resized
    /// to the output arity/shapes on first use and reused verbatim on
    /// every later call — no per-frame heap allocation.
    pub fn run_into(&self, inputs: &[&[f64]], outs: &mut Vec<Vec<f64>>) -> Result<()> {
        anyhow::ensure!(
            inputs.len() == self.input_shapes.len(),
            "{}: expected {} inputs, got {}",
            self.name,
            self.input_shapes.len(),
            inputs.len()
        );
        for (buf, shape) in inputs.iter().zip(&self.input_shapes) {
            let n: usize = shape.iter().product();
            anyhow::ensure!(
                buf.len() == n,
                "{}: input length {} != shape {:?}",
                self.name,
                buf.len(),
                shape
            );
        }
        match &self.backend {
            ExecBackend::Reference(kernel) => kernel.run_into(inputs, outs)?,
        }
        anyhow::ensure!(
            outs.len() == self.output_shapes.len(),
            "{}: expected {} outputs, got {}",
            self.name,
            self.output_shapes.len(),
            outs.len()
        );
        Ok(())
    }
}

/// The kernel runtime: resolves artifact names against the manifest
/// when present, falling back to the built-in bank geometry otherwise.
pub struct XlaRuntime {
    #[allow(dead_code)] // consumed by the pjrt backend (HLO file paths)
    dir: PathBuf,
    manifest: Option<crate::data::json::Value>,
}

impl XlaRuntime {
    /// Runtime over the default artifacts directory. Never fails on a
    /// missing directory/manifest — the reference interpreter covers
    /// the built-in kernels — but does fail on a *corrupt* manifest.
    pub fn new() -> Result<Self> {
        let dir = artifacts_dir();
        let manifest_path = dir.join("manifest.json");
        let manifest = if manifest_path.exists() {
            Some(
                crate::data::json::parse_file(&manifest_path)
                    .context("parse artifacts manifest.json")?,
            )
        } else {
            None
        };
        Ok(XlaRuntime { dir, manifest })
    }

    /// Runtime over an explicit artifacts directory; the manifest is
    /// required here (this is the "I ran `make artifacts`" entry point).
    pub fn with_dir(dir: &Path) -> Result<Self> {
        let manifest = crate::data::json::parse_file(&dir.join("manifest.json"))
            .context("read manifest.json (run `make artifacts`)")?;
        Ok(XlaRuntime { dir: dir.to_path_buf(), manifest: Some(manifest) })
    }

    /// Execution platform name. "Host" once the PJRT backend is
    /// compiled in; the reference interpreter otherwise.
    pub fn platform(&self) -> String {
        "reference-interpreter".to_string()
    }

    /// Artifact names available (manifest entries, or the built-in
    /// kernel set when running manifest-less).
    pub fn artifact_names(&self) -> Vec<String> {
        match self.manifest.as_ref().map(|m| m.req("artifacts")) {
            Some(crate::data::json::Value::Obj(m)) => m.keys().cloned().collect(),
            _ => vec!["bank_predict_iou".into(), "bank_update".into()],
        }
    }

    /// Load one artifact by name.
    pub fn load(&self, name: &str) -> Result<Artifact> {
        let (kernel, input_shapes, output_shapes) = match &self.manifest {
            Some(manifest) => {
                let entry = manifest
                    .req("artifacts")
                    .get(name)
                    .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
                let shapes = |key: &str| -> Vec<Vec<usize>> {
                    entry
                        .req(key)
                        .arr()
                        .iter()
                        .map(|io| io.arr()[1].arr().iter().map(|d| d.num() as usize).collect())
                        .collect()
                };
                let inputs = shapes("inputs");
                let outputs = shapes("outputs");
                let kernel = RefKernel::from_shapes(name, &inputs).ok_or_else(|| {
                    anyhow!("artifact '{name}' has no reference interpretation")
                })?;
                (kernel, inputs, outputs)
            }
            None => {
                let kernel = RefKernel::from_name(name).ok_or_else(|| {
                    anyhow!(
                        "artifact '{name}' unknown and no manifest present \
                         (run `make artifacts` for the full set)"
                    )
                })?;
                (kernel, kernel.input_shapes(), kernel.output_shapes())
            }
        };
        Ok(Artifact {
            backend: ExecBackend::Reference(kernel),
            name: name.to_string(),
            input_shapes,
            output_shapes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        let dir = artifacts_dir();
        assert!(dir.ends_with("artifacts") || std::env::var_os("SMALLTRACK_ARTIFACTS").is_some());
    }

    #[test]
    fn with_dir_missing_manifest_errors() {
        let err = match XlaRuntime::with_dir(Path::new("/nonexistent-dir-xyz")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest"), "{msg}");
    }

    #[test]
    fn manifestless_runtime_loads_builtin_kernels() {
        let rt = XlaRuntime { dir: PathBuf::from("/nonexistent"), manifest: None };
        for name in ["bank_predict_iou", "bank_update", "bank_predict_T4"] {
            let art = rt.load(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(art.name, name);
            assert!(!art.input_shapes.is_empty());
        }
        assert!(rt.load("bank_nonsense").is_err());
    }

    #[test]
    fn artifact_run_validates_shapes() {
        let rt = XlaRuntime { dir: PathBuf::from("/nonexistent"), manifest: None };
        let art = rt.load("bank_predict_T2").unwrap();
        // wrong arity
        assert!(art.run(&[&[0.0; 14]]).is_err());
        // wrong length
        assert!(art.run(&[&[0.0; 13], &[0.0; 98], &[0.0; 2]]).is_err());
        // correct
        let outs = art.run(&[&[0.0; 14], &[0.0; 98], &[0.0; 2]]).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].len(), 14);
        assert_eq!(outs[1].len(), 98);
    }
}
