//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas
//! tracker-bank kernels from Rust.
//!
//! Build-time Python (`make artifacts`) lowers the L2 graphs to HLO
//! *text* (see `python/compile/aot.py` for why text, not serialized
//! protos); this module compiles them once on the PJRT CPU client and
//! exposes typed entry points over `f64` buffers. Python never runs on
//! the request path — after `make artifacts` the Rust binary is
//! self-contained.
//!
//! * [`client`] — client + executable wrappers, artifact manifest.
//! * [`bank`] — the tracker-bank view: padded slot arrays + marshalling
//!   between `Sort`-style tracker state and the XLA buffers.

pub mod bank;
pub mod client;

pub use bank::{BankState, XlaSortBank};
pub use client::{artifacts_available, artifacts_dir, Artifact, XlaRuntime};
