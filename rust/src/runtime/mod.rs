//! Kernel runtime: execute the tracker-bank kernels from Rust.
//!
//! Build-time Python (`make artifacts`) lowers the L2 graphs to HLO
//! *text* (see `python/compile/aot.py` for why text, not serialized
//! protos); this module resolves artifact names/geometry and exposes
//! typed entry points over `f64` buffers. Python never runs on the
//! request path.
//!
//! Two execution backends sit behind one `Artifact` handle:
//! the PJRT CPU client (cargo feature `pjrt`, requires the `xla`
//! crate) and a pure-Rust reference interpreter of the bank kernel
//! contracts that is always available — so the `xla` engine, its tests
//! and the CLI work from a fresh clone with no artifacts at all.
//!
//! * [`client`] — artifact manifest, geometry, execution backends.
//! * [`interp`] — the reference kernel interpreter.
//! * [`bank`] — the tracker-bank view: padded slot arrays + reused
//!   marshalling buffers between `Sort`-style tracker state and the
//!   kernel buffers.

pub mod bank;
pub mod client;
pub mod interp;

pub use bank::{BankState, TrackerBank, XlaSortBank};
pub use client::{artifacts_available, artifacts_dir, Artifact, XlaRuntime};
pub use interp::RefKernel;
