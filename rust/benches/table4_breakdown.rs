//! E4 — the paper's Table IV (per-step % time + arithmetic intensity)
//! and Fig 3 (the cprofile-style breakdown of the Update function),
//! from the live phase instrumentation.

use smalltrack::benchkit::{BenchArgs, BenchReport, Table};
use smalltrack::data::synth::generate_suite;
use smalltrack::sort::{Bbox, Phase, Sort, SortParams};

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("table4_breakdown", &args);
    let mut suite = generate_suite(7);
    if args.smoke {
        suite.truncate(3);
    }
    // one tracker reused per sequence (like the paper's runs), phases merged
    let mut merged = smalltrack::sort::PhaseTimer::new(true);
    let mut boxes: Vec<Bbox> = Vec::new();
    for s in &suite {
        let mut sort = Sort::new(SortParams { dense_kernels: true, ..Default::default() });
        for frame in &s.sequence.frames {
            boxes.clear();
            boxes.extend(frame.detections.iter().map(|d| d.bbox));
            sort.update(&boxes);
        }
        merged.merge(&sort.phases);
    }

    let pct = merged.percentages();
    let mut table = Table::new(
        "Table IV — algorithm steps, % of time and arithmetic intensity (measured)",
        &["Step", "% of time", "AI (flops/byte)", "calls", "paper %", "paper AI"],
    );
    let paper: [(&str, f64, f64); 5] = [
        ("6.2 predict", 30.0, 2.4),
        ("6.3 assignment", 22.2, 1.5),
        ("6.4 update", 34.3, 18.0),
        ("6.6 create new", 3.1, 0.1),
        ("6.7 prepare output", 9.9, 1.0),
    ];
    for (phase, (label, p_pct, p_ai)) in Phase::ALL.iter().zip(&paper) {
        let s = merged.get(*phase);
        assert_eq!(phase.label(), *label);
        table.row(&[
            label.to_string(),
            format!("{:.1}", pct[*phase as usize]),
            format!("{:.2}", s.ai_ws()),
            format!("{}", s.count),
            format!("{p_pct:.1}"),
            format!("{p_ai:.1}"),
        ]);
    }
    table.print();
    report.add_table(&table);
    report.finish().unwrap();

    // Fig 3: text bar chart of the Update-function profile
    println!("\nFig 3 — profile of the update function (this implementation):");
    for phase in Phase::ALL {
        let p = pct[phase as usize];
        let bar = "#".repeat((p / 2.0).round() as usize);
        println!("  {:<20} {:>5.1}% {}", phase.label(), p, bar);
    }

    // shape assertions: predict+update dominate; update has the top AI
    // (working-set AI: flops per byte of data the step actually touches,
    // the accounting the paper's Table IV uses — update re-reads the same
    // 7x7 covariance across ~15 kernel calls, hence its 10x higher AI)
    let ai_update = merged.get(Phase::Update).ai_ws();
    let ai_predict = merged.get(Phase::Predict).ai_ws();
    let ai_assign = merged.get(Phase::Assign).ai_ws();
    println!("\nshape checks vs paper:");
    println!("  update AI {ai_update:.2} > predict AI {ai_predict:.2} > assign AI {ai_assign:.2}");
    assert!(ai_update > ai_predict, "update must have the highest AI (paper: 18 vs 2.4)");
    assert!(ai_predict > ai_assign, "predict AI must beat assignment (paper: 2.4 vs 1.5)");
    assert!(
        pct[Phase::Predict as usize] + pct[Phase::Update as usize] > 40.0,
        "KF phases must dominate ({pct:?})"
    );
}
