//! E8 (ours) — the paper's "tiny matrices" thesis, measured on an
//! accelerator-shaped stack: per-step latency of the native Rust
//! Kalman bank vs the AOT-compiled XLA bank at growing bank sizes.
//!
//! Expectation: at T=1 the native path wins by orders of magnitude
//! (kernel-dispatch overhead dominates, the multicore analog of the
//! paper's strong-scaling result); the XLA path amortizes as T grows —
//! batching across independent trackers/streams is the accelerator
//! analog of throughput scaling.
//!
//! Requires `make artifacts`; exits 0 with a notice if missing.

use smalltrack::benchkit::{bench, fmt_duration, BenchConfig, Table};
use smalltrack::runtime::{artifacts_available, XlaRuntime};
use smalltrack::sort::kalman::{KalmanState, SortConstants};

fn main() {
    if !artifacts_available() {
        println!("artifacts missing — run `make artifacts` first; skipping");
        return;
    }
    let rt = XlaRuntime::new().expect("PJRT client");
    let consts = SortConstants::sort_defaults();
    let cfg = BenchConfig::default();

    let mut table = Table::new(
        "E8 — batched Kalman predict: native loop vs AOT/XLA bank",
        &["bank T", "native/step", "xla/step", "native/tracker", "xla/tracker", "xla win?"],
    );

    for t in [1usize, 4, 16, 64, 256] {
        // native: T sequential KalmanState::predict calls
        let mut states: Vec<KalmanState> = (0..t)
            .map(|i| {
                KalmanState::from_measurement(
                    &[100.0 + i as f64, 50.0, 2000.0, 0.5],
                    &consts,
                )
            })
            .collect();
        let native = bench(&format!("native T={t}"), &cfg, t as u64, || {
            for s in states.iter_mut() {
                s.predict(&consts);
                // keep numbers bounded over millions of iterations
                if s.p[(0, 0)] > 1e9 {
                    *s = KalmanState::from_measurement(&[100.0, 50.0, 2000.0, 0.5], &consts);
                }
            }
        });

        // xla: one bank_predict_T{t} execution
        let art = rt.load(&format!("bank_predict_T{t}")).expect("artifact");
        let x = vec![1.0; t * 7];
        let p = vec![0.5; t * 49];
        let mask = vec![1.0; t];
        let xla = bench(&format!("xla T={t}"), &cfg, t as u64, || {
            art.run(&[&x, &p, &mask]).expect("run")
        });

        let n_step = native.median();
        let x_step = xla.median();
        table.row(&[
            format!("{t}"),
            fmt_duration(n_step),
            fmt_duration(x_step),
            fmt_duration(n_step / t as f64),
            fmt_duration(x_step / t as f64),
            format!("{:.1}x native", x_step / n_step),
        ]);
    }
    table.print();

    println!("\nthe ratio shrinking with T is the paper's argument transposed to an");
    println!("accelerator: tiny per-item work cannot amortize dispatch — batch the");
    println!("independent items (trackers/streams) instead of splitting one item.");
}
