//! E8 (ours) — the paper's "tiny matrices" thesis, measured on an
//! accelerator-shaped stack, end to end *and* per kernel step.
//!
//! Part A compares full tracker engines through the [`TrackerEngine`]
//! trait — the same code path the coordinator serves — on a shared
//! synthetic sequence: `native` vs `strong` vs `xla`. This runs
//! everywhere (the bank falls back to the reference interpreter when
//! `make artifacts` has not produced the compiled kernels).
//!
//! Part B is the per-step bank sweep: batched Kalman predict at growing
//! bank sizes T, native loop vs one bank-kernel dispatch. Expectation:
//! at T=1 the native path wins by orders of magnitude (kernel-dispatch
//! overhead dominates — the accelerator analog of the paper's
//! strong-scaling result); the bank amortizes as T grows, which is the
//! accelerator analog of throughput scaling.

use smalltrack::benchkit::{bench, fmt_duration, BenchArgs, BenchConfig, BenchReport, Table};
use smalltrack::data::synth::{generate_sequence, SynthConfig};
use smalltrack::engine::{run_sequence, EngineKind, TrackerEngine};
use smalltrack::runtime::{artifacts_available, XlaRuntime};
use smalltrack::sort::kalman::{KalmanState, SortConstants};
use smalltrack::sort::SortParams;

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("xla_vs_native", &args);
    let cfg = if args.smoke { BenchConfig::smoke() } else { BenchConfig::quick() };
    let e2e_frames: u32 = if args.smoke { 100 } else { 300 };
    let params = SortParams { timing: false, ..Default::default() };
    let rt = XlaRuntime::new().expect("kernel runtime");

    // --- Part A: whole engines through the trait, one shared workload
    let synth = generate_sequence(&SynthConfig::mot15("E8-e2e", e2e_frames, 8, 21));
    let frames = synth.sequence.n_frames() as u64;
    let mut table = Table::new(
        &format!(
            "E8a — end-to-end engines on one {e2e_frames}-frame stream (xla backend: {})",
            rt.platform()
        ),
        &["engine", "time/stream", "us/frame", "fps", "tracks"],
    );
    let mut baseline_tracks = None;
    for kind in EngineKind::all(2) {
        let mut engine = kind.build(params).expect("build engine");
        let mut tracks = 0u64;
        let m = bench(kind.label(), &cfg, frames, || {
            engine.reset();
            tracks = run_sequence(&mut *engine, &synth.sequence).1;
        });
        // engines must agree on output — the comparison is meaningless
        // otherwise
        match baseline_tracks {
            None => baseline_tracks = Some(tracks),
            Some(want) => assert_eq!(tracks, want, "engine {} diverged", kind.label()),
        }
        table.row(&[
            kind.label().to_string(),
            fmt_duration(m.median()),
            format!("{:.2}", m.median() * 1e6 / frames as f64),
            format!("{:.0}", m.rate()),
            format!("{tracks}"),
        ]);
    }
    table.print();
    report.add_table(&table);
    println!("\ndispatch asymmetry at bank size ~8 IS the paper's thesis: per-item");
    println!("work this small cannot amortize a kernel (or thread) launch.");

    // --- Part B: per-step bank sweep (needs the AOT kernel geometry
    // for the larger bank sizes; built-in geometry covers the rest)
    if !artifacts_available() {
        println!("\n(artifacts missing — run `make artifacts` for the compiled-kernel");
        println!(" sweep; E8b below uses the reference interpreter geometry)");
    }
    let consts = SortConstants::sort_defaults();
    let mut sweep = Table::new(
        "E8b — batched Kalman predict: native loop vs bank kernel",
        &["bank T", "native/step", "bank/step", "native/tracker", "bank/tracker", "bank cost"],
    );
    let bank_sizes: &[usize] = if args.smoke { &[1, 4, 16] } else { &[1, 4, 16, 64, 256] };
    for &t in bank_sizes {
        // native: T sequential KalmanState::predict calls
        let mut states: Vec<KalmanState> = (0..t)
            .map(|i| {
                KalmanState::from_measurement(
                    &[100.0 + i as f64, 50.0, 2000.0, 0.5],
                    &consts,
                )
            })
            .collect();
        let native = bench(&format!("native T={t}"), &cfg, t as u64, || {
            for s in states.iter_mut() {
                s.predict(&consts);
                // keep numbers bounded over millions of iterations
                if s.p[(0, 0)] > 1e9 {
                    *s = KalmanState::from_measurement(&[100.0, 50.0, 2000.0, 0.5], &consts);
                }
            }
        });

        // bank: one bank_predict_T{t} dispatch, outputs reused
        let art = rt.load(&format!("bank_predict_T{t}")).expect("artifact");
        let x = vec![1.0; t * 7];
        let p = vec![0.5; t * 49];
        let mask = vec![1.0; t];
        let mut outs = Vec::new();
        let bank_m = bench(&format!("bank T={t}"), &cfg, t as u64, || {
            art.run_into(&[&x, &p, &mask], &mut outs).expect("run")
        });

        let n_step = native.median();
        let x_step = bank_m.median();
        sweep.row(&[
            format!("{t}"),
            fmt_duration(n_step),
            fmt_duration(x_step),
            fmt_duration(n_step / t as f64),
            fmt_duration(x_step / t as f64),
            format!("{:.1}x native", x_step / n_step),
        ]);
    }
    sweep.print();
    report.add_table(&sweep);
    report.finish().unwrap();

    println!("\nthe ratio shrinking with T is the paper's argument transposed to an");
    println!("accelerator: tiny per-item work cannot amortize dispatch — batch the");
    println!("independent items (trackers/streams) instead of splitting one item.");
}
