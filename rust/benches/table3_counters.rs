//! E3 — the paper's Table III: hardware counters for the tracking run.
//!
//! Bare-metal counters are unreliable in this virtualized 1-core box,
//! so the primary row comes from the analytic model in
//! `rust/src/perfmodel.rs` (instructions from the instrumented flop/
//! call counts; cache/TLB/BW from the working-set model). If a usable
//! `perf stat` exists, a measured row is printed next to it.

use smalltrack::benchkit::{BenchArgs, BenchReport, Table};
use smalltrack::coordinator::policy::run_sequence_serial;
use smalltrack::data::synth::generate_suite;
use smalltrack::linalg::{reset_counters, snapshot};
use smalltrack::perfmodel::{estimate, run_under_perf};
use smalltrack::sort::SortParams;
use std::time::Instant;

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("table3_counters", &args);
    let mut suite = generate_suite(7);
    if args.smoke {
        // the analytic model is per-frame — a subset keeps every
        // shape assertion while cutting the run to seconds
        suite.truncate(3);
    }

    // counted run (instrumentation on)
    reset_counters();
    for s in &suite {
        // dense kernels: the paper profiles a dense-library implementation
        run_sequence_serial(s, SortParams { dense_kernels: true, ..Default::default() });
    }
    let counters = snapshot();

    // timed run (instrumentation off; dense kernels to match the
    // counted run — Table III characterizes the dense formulation)
    let t0 = Instant::now();
    for s in &suite {
        run_sequence_serial(
            s,
            SortParams { timing: false, dense_kernels: true, ..Default::default() },
        );
    }
    let wall = t0.elapsed();

    let frames: usize = suite.iter().map(|s| s.sequence.n_frames()).sum();
    let e = estimate(&counters, wall);
    let mut table = Table::new(
        &format!("Table III — hardware counters for object tracking ({frames} frames)"),
        &["source", "Instructions", "Time (s)", "IPC", "TLB MPKI", "LLC MPKI", "BW usage"],
    );
    table.row(&[
        "model (this impl)".into(),
        format!("{:.3e}", e.instructions),
        format!("{:.4}", e.time.as_secs_f64()),
        format!("{:.2}", e.ipc),
        format!("{:.3}", e.tlb_mpki),
        format!("{:.3}", e.llc_mpki),
        format!("{:.4}%", e.bw_usage * 100.0),
    ]);
    table.row(&[
        "paper (python orig.)".into(),
        "4.755e10".into(),
        "10".into(),
        "2.21".into(),
        "0.136".into(),
        "0.059".into(),
        "0.015%".into(),
    ]);

    // optional: real perf stat on the CLI binary
    let exe = std::env::current_exe().ok().and_then(|p| {
        // benches live in target/release/deps; the CLI sits two dirs up
        p.parent()?.parent().map(|d| d.join("smalltrack"))
    });
    if let Some(exe) = exe.filter(|p| p.exists()) {
        let mut cmd = std::process::Command::new(exe);
        cmd.arg("suite");
        if let Some(stat) = run_under_perf(cmd) {
            table.row(&[
                "perf stat (measured)".into(),
                stat.instructions.map(|v| format!("{v:.3e}")).unwrap_or("-".into()),
                "-".into(),
                stat.ipc().map(|v| format!("{v:.2}")).unwrap_or("-".into()),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
        } else {
            println!("(perf stat unavailable in this sandbox — model row only)");
        }
    }
    table.print();
    report.add_table(&table);
    report.finish().unwrap();

    println!("\nshape check vs paper: low MPKI (working set ≪ LLC), sub-1% BW — the");
    println!("workload is compute-dispatch-bound, not memory-bound. Our native run");
    println!("does the same frames in {:.3}s vs the paper-python's 10s.", wall.as_secs_f64());
    assert!(e.llc_mpki < 1.0 && e.tlb_mpki < 1.0 && e.bw_usage < 0.01);
}
