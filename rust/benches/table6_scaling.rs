//! E6 — the paper's Table VI: strong/weak/throughput scaling FPS at
//! p ∈ {1, 18, 36, 72}.
//!
//! Three parts:
//!  (a) measured on this machine at small p (real threads — on a 1-core
//!      box the oversubscription *shows* the strong-scaling overhead);
//!  (b) the work-stealing shard scheduler, pinned vs stealing, across
//!      worker counts on a deliberately heterogeneous suite (the
//!      runtime the paper's throughput column grows into);
//!  (c) the calibrated discrete-event simulation at the paper's core
//!      counts on the SKX-6140 profile (see rust/src/simcore/).

use smalltrack::benchkit::{BenchArgs, BenchReport, Table};
use smalltrack::coordinator::policy::{outcomes_consistent, run_policy, ScalingPolicy};
use smalltrack::coordinator::scheduler::{run_shards, SchedulerConfig, ShardPolicy};
use smalltrack::data::synth::generate_suite;
use smalltrack::simcore::{calibrate_workload, simulate, MachineProfile, SimPolicy};
use smalltrack::sort::SortParams;

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("table6_scaling", &args);
    let mut suite = generate_suite(7);
    if args.smoke {
        // first 4 sequences (795+71+179+1000 frames): heterogeneous
        // enough for every shape assertion, seconds instead of minutes
        suite.truncate(4);
    }
    let n_files = suite.len();
    let n_frames: usize = suite.iter().map(|s| s.sequence.n_frames()).sum();
    let reps: u32 = if args.smoke { 1 } else { 3 };
    let thread_counts: &[usize] = if args.smoke { &[1, 2] } else { &[1, 2, 4] };
    let params = SortParams { timing: false, ..Default::default() };

    // (a) measured
    let mut measured = Table::new(
        "Table VI(a) — measured on this testbed (FPS, wall-clock)",
        &["Threads", "files", "frames", "Strong", "Weak", "Throughput"],
    );
    for &p in thread_counts {
        let mut row = vec![format!("{p}"), format!("{n_files}"), format!("{n_frames}")];
        let mut outs = Vec::new();
        for policy in [
            ScalingPolicy::Strong { threads: p },
            ScalingPolicy::Weak { workers: p },
            ScalingPolicy::Throughput { workers: p },
        ] {
            // best of N for stability
            let mut best_fps = 0.0f64;
            let mut last = None;
            for _ in 0..reps {
                let o = run_policy(&suite, policy, params);
                best_fps = best_fps.max(o.fps());
                last = Some(o);
            }
            row.push(format!("{best_fps:.0}"));
            outs.push(last.unwrap());
        }
        assert!(outcomes_consistent(&outs), "policies disagree on output");
        measured.row(&row);
    }
    measured.print();
    report.add_table(&measured);

    // (b) shard scheduler: pinned vs stealing across worker counts.
    // The Table I suite is heterogeneous (71..1000 frames), which is
    // exactly where static pinning strands work on the unlucky shard.
    let mut shards = Table::new(
        "Table VI(b) — shard scheduler, pinned vs stealing (FPS, wall-clock)",
        &["Workers", "Pinned", "Stealing", "stolen", "steal/pin"],
    );
    let baseline_tracks = {
        let o = run_policy(&suite, ScalingPolicy::Weak { workers: 1 }, params);
        o.tracks_out
    };
    for &p in thread_counts {
        let mut fps = [0.0f64; 2];
        let mut stolen = 0u64;
        for (i, policy) in [ShardPolicy::Pinned, ShardPolicy::Stealing].iter().enumerate() {
            // best of N for stability
            for _ in 0..reps {
                let r = run_shards(
                    &suite,
                    SchedulerConfig {
                        workers: p,
                        shard_policy: *policy,
                        sort_params: params,
                        ..Default::default()
                    },
                );
                assert_eq!(r.tracks_out, baseline_tracks, "shard scheduler changed the output");
                assert_eq!(r.shed, 0, "Block admission must be lossless");
                if r.fps() > fps[i] {
                    fps[i] = r.fps();
                    // report the steal count of the run whose FPS the
                    // row shows (pinned runs are always 0)
                    if *policy == ShardPolicy::Stealing {
                        stolen = r.stolen;
                    }
                }
            }
        }
        shards.row(&[
            format!("{p}"),
            format!("{:.0}", fps[0]),
            format!("{:.0}", fps[1]),
            format!("{stolen}"),
            format!("{:.2}x", fps[1] / fps[0]),
        ]);
    }
    shards.print();
    report.add_table(&shards);

    // (c) simulated at the paper's scale
    let w = calibrate_workload(&suite, reps);
    let m = MachineProfile::skx6140();
    let mut sim = Table::new(
        "Table VI(c) — calibrated simulation, SKX-6140 profile (paper's machine)",
        &["Cores", "files", "frames", "Strong", "Weak", "Throughput"],
    );
    let mut strong_series = Vec::new();
    let mut weak_series = Vec::new();
    let mut tp_series = Vec::new();
    for p in [1usize, 18, 36, 72] {
        let s = simulate(&w, &m, SimPolicy::Strong { threads: p }).fps_paper_metric;
        let wk = simulate(&w, &m, SimPolicy::Weak { cores: p }).fps_paper_metric;
        let tp = simulate(&w, &m, SimPolicy::Throughput { cores: p }).fps_paper_metric;
        strong_series.push(s);
        weak_series.push(wk);
        tp_series.push(tp);
        sim.row(&[
            format!("{p}"),
            format!("{n_files}"),
            format!("{n_frames}"),
            format!("{s:.1}"),
            format!("{wk:.1}"),
            format!("{tp:.1}"),
        ]);
    }
    sim.print();
    report.add_table(&sim);

    let mut paper = Table::new(
        "Table VI (paper, for comparison)",
        &["Cores", "files", "frames", "Strong", "Weak", "Throughput"],
    );
    for (p, s, w_, t) in [
        (1, 37415.0, 45082.0, 47573.0),
        (18, 24663.7, 34810.1, 37450.0),
        (36, 23404.3, 37162.2, 37489.0),
        (72, 19503.5, 31976.7, 38400.0),
    ] {
        paper.row(&[
            format!("{p}"),
            "11".into(),
            "5500".into(),
            format!("{s}"),
            format!("{w_}"),
            format!("{t}"),
        ]);
    }
    paper.print();
    report.add_table(&paper);
    report.finish().unwrap();

    // headline shape assertions
    println!("\nshape checks:");
    println!("  strong degrades with p: {strong_series:?}");
    assert!(strong_series[0] > strong_series[1] && strong_series[1] > strong_series[3]);
    println!("  throughput sustains within 15% from 18..72 cores: {tp_series:?}");
    let tp_min = tp_series[1..].iter().cloned().fold(f64::INFINITY, f64::min);
    let tp_max = tp_series[1..].iter().cloned().fold(0.0f64, f64::max);
    assert!(tp_max / tp_min < 1.15);
    println!("  throughput >= weak at every p");
    for i in 0..4 {
        assert!(tp_series[i] >= weak_series[i] * 0.99);
    }
    println!("  crossover: strong loses to weak/throughput at every multi-core point");
    for i in 1..4 {
        assert!(strong_series[i] < weak_series[i]);
    }
}
