//! E1 — regenerate the paper's Table I (dataset properties) from the
//! synthetic suite and verify the generator hits the published numbers.

use smalltrack::benchkit::{BenchArgs, BenchReport, Table};
use smalltrack::data::synth::{generate_suite, MOT15_PROPERTIES};

fn main() {
    // no timing here — smoke mode is identical; --json still archives
    // the generated dataset properties next to the perf reports
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("table1_dataset", &args);
    let suite = generate_suite(7);
    let mut table = Table::new(
        "Table I — dataset properties (synthetic MOT-2015 substitution)",
        &["Dataset (video)", "#Frames", "Max Tracked Object", "dets/frame", "total dets"],
    );
    let mut ok = true;
    for (s, &(name, frames, max_obj)) in suite.iter().zip(&MOT15_PROPERTIES) {
        // measured max simultaneous ground-truth objects
        let per_frame_gt = {
            let mut pf = vec![0u32; s.sequence.n_frames() + 1];
            for t in &s.ground_truth {
                for (f, _) in &t.boxes {
                    pf[*f as usize] += 1;
                }
            }
            pf.into_iter().max().unwrap_or(0)
        };
        if s.sequence.n_frames() as u32 != frames || per_frame_gt != max_obj {
            ok = false;
        }
        table.row(&[
            name.to_string(),
            format!("{}", s.sequence.n_frames()),
            format!("{per_frame_gt}"),
            format!("{:.2}", s.sequence.n_detections() as f64 / s.sequence.n_frames() as f64),
            format!("{}", s.sequence.n_detections()),
        ]);
    }
    let total: usize = suite.iter().map(|s| s.sequence.n_frames()).sum();
    table.row(&[
        "TOTAL (11 files)".into(),
        format!("{total}"),
        "13".into(),
        "-".into(),
        format!("{}", suite.iter().map(|s| s.sequence.n_detections()).sum::<usize>()),
    ]);
    table.print();
    report.add_table(&table);
    report.finish().unwrap();
    println!("\npaper: 11 files, 5500 frames, max 13 simultaneous objects");
    println!(
        "match: frames_total={} (want 5500), per-sequence properties {}",
        total,
        if ok { "MATCH" } else { "MISMATCH" }
    );
    assert_eq!(total, 5500);
    assert!(ok, "generator drifted from Table I");
}
