//! Microbenchmarks of the small-matrix kernels (Table II shapes).
//!
//! The per-op latencies here justify the paper's core claim: a 7×7
//! GEMM is tens of nanoseconds — thousands of times smaller than a
//! thread wake-up — so intra-frame parallelism can never pay.

use smalltrack::benchkit::{bench, BenchArgs, BenchReport, Measurement, Table};
use smalltrack::linalg::{chol_inverse, cholesky, set_counters_enabled, Mat, Mat4, Mat4x7, Mat7};

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("micro_linalg", &args);
    set_counters_enabled(false); // pure-speed numbers
    let cfg = args.config();

    let f = {
        let mut f = Mat7::eye();
        f[(0, 4)] = 1.0;
        f[(1, 5)] = 1.0;
        f[(2, 6)] = 1.0;
        f
    };
    let p = {
        let mut p = Mat7::eye().scale(3.0);
        for i in 0..6 {
            p[(i, i + 1)] = 0.4;
            p[(i + 1, i)] = 0.4;
        }
        p
    };
    let h = {
        let mut h = Mat4x7::zeros();
        for i in 0..4 {
            h[(i, i)] = 1.0;
        }
        h
    };
    let s4: Mat4 = {
        let ph = p.matmul_nt(&h);
        h.matmul(&ph).add(&Mat4::diag(&[1.0, 1.0, 10.0, 10.0]))
    };
    let x = [1.0, 2.0, 3.0, 0.5, 0.1, 0.2, 0.3];

    let mut rows: Vec<Measurement> = Vec::new();
    rows.push(bench("gemm 7x7 * 7x7", &cfg, 1, || std::hint::black_box(f.matmul(&p))));
    rows.push(bench("gemm 4x7 * 7x7", &cfg, 1, || std::hint::black_box(h.matmul(&p))));
    rows.push(bench("gemm_nt 7x7 * (7x7)^T", &cfg, 1, || std::hint::black_box(p.matmul_nt(&f))));
    rows.push(bench("gemv 7x7 * 7", &cfg, 1, || std::hint::black_box(f.matvec(&x))));
    rows.push(bench("transpose 4x7", &cfg, 1, || std::hint::black_box(h.transpose())));
    rows.push(bench("cholesky 4x4", &cfg, 1, || std::hint::black_box(cholesky(&s4))));
    rows.push(bench("spd inverse 4x4", &cfg, 1, || std::hint::black_box(chol_inverse(&s4))));
    rows.push(bench("cholesky 7x7", &cfg, 1, || std::hint::black_box(cholesky(&p))));
    rows.push(bench("add 7x7", &cfg, 1, || std::hint::black_box(p.add(&f))));
    rows.push(bench("symmetrize 7x7", &cfg, 1, || std::hint::black_box(p.symmetrize())));

    let mut table = Table::new(
        "micro — small-matrix kernel latencies (the paper's Table II shapes)",
        &["kernel", "median", "mean", "min"],
    );
    for m in &rows {
        table.row(&[
            m.name.clone(),
            smalltrack::benchkit::fmt_duration(m.median()),
            smalltrack::benchkit::fmt_duration(m.mean()),
            smalltrack::benchkit::fmt_duration(m.min()),
        ]);
    }
    table.print();
    report.add_table(&table);
    for m in &rows {
        report.add_measurement(m);
    }
    report.finish().unwrap();

    let gemm = rows[0].median();
    println!("\n7x7 GEMM = {}; a futex wake alone is ~2-10us — parallelizing", smalltrack::benchkit::fmt_duration(gemm));
    println!("inside a frame buys {:.0}x less work than the wake costs.", 3e-6 / gemm);
    set_counters_enabled(true);
}
