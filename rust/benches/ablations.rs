//! E9 — design-choice ablations called out in DESIGN.md §6:
//!  1. Hungarian vs greedy association — speed and tracking quality
//!     (id churn against synthetic ground truth, incl. a crossing-
//!     objects stress);
//!  2. Joseph-form vs simple covariance update — speed and numerical
//!     health (covariance asymmetry after long runs);
//!  3. the original's fast-path (skip the assignment solve when the
//!     thresholded IoU matrix is already a partial permutation).

use smalltrack::benchkit::{bench, fmt_duration, BenchArgs, BenchReport, Table};
use smalltrack::coordinator::policy::run_sequence_serial;
use smalltrack::data::synth::{generate_sequence, SynthConfig};
use smalltrack::sort::kalman::{CovarianceForm, KalmanState, SortConstants};
use smalltrack::sort::{AssociationMethod, Bbox, Sort, SortParams};

/// Count identity switches: a ground-truth object whose matched track
/// id changes between consecutive frames.
fn id_switches(synth: &smalltrack::data::synth::SynthSequence, method: AssociationMethod) -> u64 {
    let mut sort = Sort::new(SortParams { method, timing: false, ..Default::default() });
    let mut last_id: std::collections::HashMap<u64, u64> = Default::default();
    let mut switches = 0u64;
    let mut boxes: Vec<Bbox> = Vec::new();
    // gt boxes by frame
    let mut gt_by_frame: std::collections::HashMap<u32, Vec<(u64, Bbox)>> = Default::default();
    for t in &synth.ground_truth {
        for (f, b) in &t.boxes {
            gt_by_frame.entry(*f).or_default().push((t.id, *b));
        }
    }
    for frame in &synth.sequence.frames {
        boxes.clear();
        boxes.extend(frame.detections.iter().map(|d| d.bbox));
        let tracks = sort.update(&boxes).to_vec();
        if let Some(gts) = gt_by_frame.get(&frame.index) {
            for (gt_id, gt_box) in gts {
                // best-overlap track for this gt object
                let best = tracks
                    .iter()
                    .map(|t| (t.id, smalltrack::sort::iou::iou(&t.bbox, gt_box)))
                    .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                if let Some((tid, ov)) = best {
                    if ov > 0.4 {
                        if let Some(&prev) = last_id.get(gt_id) {
                            if prev != tid {
                                switches += 1;
                            }
                        }
                        last_id.insert(*gt_id, tid);
                    }
                }
            }
        }
    }
    switches
}

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("ablations", &args);
    let cfg = args.config();
    let frames: u32 = if args.smoke { 120 } else { 400 };
    let health_frames: usize = if args.smoke { 2_000 } else { 20_000 };

    // --- 1. association method
    let crowded = generate_sequence(&SynthConfig::mot15("crowded", frames, 13, 99));
    let hung_t = bench("hungarian suite", &cfg, frames as u64, || {
        run_sequence_serial(
            &crowded,
            SortParams { method: AssociationMethod::Hungarian, timing: false, ..Default::default() },
        )
    });
    let greedy_t = bench("greedy suite", &cfg, frames as u64, || {
        run_sequence_serial(
            &crowded,
            SortParams { method: AssociationMethod::Greedy, timing: false, ..Default::default() },
        )
    });
    let sw_h = id_switches(&crowded, AssociationMethod::Hungarian);
    let sw_g = id_switches(&crowded, AssociationMethod::Greedy);

    let mut t1 = Table::new(
        "E9.1 — association: Hungarian (SORT) vs greedy",
        &["method", "time / seq", "id switches (crowded, 13 obj)"],
    );
    t1.row(&["hungarian".into(), fmt_duration(hung_t.median()), format!("{sw_h}")]);
    t1.row(&["greedy".into(), fmt_duration(greedy_t.median()), format!("{sw_g}")]);
    t1.print();
    report.add_table(&t1);
    report.add_measurement(&hung_t);
    report.add_measurement(&greedy_t);
    assert!(sw_h <= sw_g, "optimal assignment must not churn more than greedy");

    // --- 2. covariance form
    let consts = SortConstants::sort_defaults();
    fn kf_step(consts: &SortConstants, form: CovarianceForm) -> impl FnMut() + '_ {
        let mut s = KalmanState::from_measurement(&[100.0, 100.0, 2000.0, 0.5], consts);
        move || {
            s.predict(consts);
            s.update(&[101.0, 100.5, 2010.0, 0.5], consts, form);
            if s.p[(0, 0)] > 1e9 {
                s = KalmanState::from_measurement(&[100.0, 100.0, 2000.0, 0.5], consts);
            }
        }
    }
    let joseph_t = bench("joseph step", &cfg, 1, kf_step(&consts, CovarianceForm::Joseph));
    let simple_t = bench("simple step", &cfg, 1, kf_step(&consts, CovarianceForm::Simple));

    // numerical health over a long run
    let asym = |form: CovarianceForm| {
        let mut s = KalmanState::from_measurement(&[100.0, 100.0, 2000.0, 0.5], &consts);
        let mut max_asym = 0.0f64;
        for k in 0..health_frames {
            s.predict(&consts);
            s.update(
                &[100.0 + (k % 7) as f64, 100.0, 2000.0 + (k % 13) as f64, 0.5],
                &consts,
                form,
            );
            max_asym = max_asym.max(s.p.asymmetry());
        }
        max_asym
    };
    let asym_j = asym(CovarianceForm::Joseph);
    let asym_s = asym(CovarianceForm::Simple);

    let mut t2 = Table::new(
        "E9.2 — covariance update: Joseph form (filterpy/SORT) vs simple",
        &["form", "time / KF step", "max P asymmetry (long run)"],
    );
    t2.row(&["joseph".into(), fmt_duration(joseph_t.median()), format!("{asym_j:.2e}")]);
    t2.row(&["simple".into(), fmt_duration(simple_t.median()), format!("{asym_s:.2e}")]);
    t2.print();
    report.add_table(&t2);
    report.add_measurement(&joseph_t);
    report.add_measurement(&simple_t);
    println!("(joseph costs ~2 extra 7x7 GEMMs per update — the price of guaranteed SPD)");

    // --- 3. fast path: sparse (unambiguous) vs crowded frames
    let sparse = generate_sequence(&SynthConfig::mot15("sparse", frames, 3, 5));
    let sparse_t = bench("sparse fast-path", &cfg, frames as u64, || {
        run_sequence_serial(&sparse, SortParams { timing: false, ..Default::default() })
    });
    let crowded_t = bench("crowded full-hungarian", &cfg, frames as u64, || {
        run_sequence_serial(&crowded, SortParams { timing: false, ..Default::default() })
    });
    let mut t3 = Table::new(
        "E9.3 — assignment fast-path effect (sparse scenes skip the solver)",
        &["scene", "objects", "time / seq", "us/frame"],
    );
    t3.row(&[
        "sparse".into(),
        "<=3".into(),
        fmt_duration(sparse_t.median()),
        format!("{:.2}", sparse_t.median() * 1e6 / frames as f64),
    ]);
    t3.row(&[
        "crowded".into(),
        "<=13".into(),
        fmt_duration(crowded_t.median()),
        format!("{:.2}", crowded_t.median() * 1e6 / frames as f64),
    ]);
    t3.print();
    report.add_table(&t3);

    // --- 4. dense library kernels vs structure-aware fast path (§Perf)
    let fast_t = bench("fast kernels", &cfg, frames as u64, || {
        run_sequence_serial(&crowded, SortParams { timing: false, ..Default::default() })
    });
    let dense_t = bench("dense kernels", &cfg, frames as u64, || {
        run_sequence_serial(
            &crowded,
            SortParams { timing: false, dense_kernels: true, ..Default::default() },
        )
    });
    let q_fast = smalltrack::sort::quality::evaluate_sort(
        &crowded,
        SortParams { timing: false, ..Default::default() },
        0.5,
    );
    let q_dense = smalltrack::sort::quality::evaluate_sort(
        &crowded,
        SortParams { timing: false, dense_kernels: true, ..Default::default() },
        0.5,
    );
    let mut t4 = Table::new(
        "E9.4 — dense library GEMMs (paper's formulation) vs structure-aware kernels",
        &["kernels", "time / seq", "speedup", "MOTA", "id switches"],
    );
    t4.row(&[
        "dense (F,H as GEMMs)".into(),
        fmt_duration(dense_t.median()),
        "1.0x".into(),
        format!("{:.3}", q_dense.mota()),
        format!("{}", q_dense.id_switches),
    ]);
    t4.row(&[
        "structure-aware".into(),
        fmt_duration(fast_t.median()),
        format!("{:.2}x", dense_t.median() / fast_t.median()),
        format!("{:.3}", q_fast.mota()),
        format!("{}", q_fast.id_switches),
    ]);
    t4.print();
    report.add_table(&t4);
    report.finish().unwrap();
    assert_eq!(q_fast, q_dense, "kernel choice must not change tracking output");
    assert!(fast_t.median() < dense_t.median(), "fast path must win");
}
