//! Batch-vs-native engine bench: per-frame latency, single-stream
//! throughput, and the kernel-counter instrumentation tax.
//!
//! The paper's thesis applied to our own hot loop: at 7×7 matrices the
//! per-tracker *overhead* (pointer chasing across `KalmanBoxTracker`
//! objects, one counter bump per kernel call) rivals the arithmetic.
//! The `batch` engine keeps all trackers in SoA lanes and records one
//! counter event per kernel kind per frame; this bench measures what
//! that buys at 1 / 8 / 32 trackers per frame, with the thread-local
//! counters enabled and runtime-disabled. (Compile with
//! `--no-default-features` to remove the instrumentation entirely —
//! the residual "off" tax below is the branch the feature deletes.)
//!
//! A second table ablates the batch engine's execution knobs: lane
//! width (scalar / 4-wide / 8-wide blocks) × precision tier (f64 /
//! f32), against the scalar-f64 row as reference. Every f64 row is
//! gated bitwise against the native engine before any timing — lane
//! width is a pure execution detail and must never move a bit.
//!
//! Run modes: `cargo bench --bench batch_vs_native` (full), or append
//! `smoke` (CI) for a seconds-long pass with the same table shape;
//! `--json <path>` writes the table as a machine-readable report.

use smalltrack::benchkit::{bench, fmt_duration, BenchArgs, BenchConfig, BenchReport, Table};
use smalltrack::data::synth::{generate_sequence, SynthConfig, SynthSequence};
use smalltrack::engine::{run_sequence, EngineKind, TrackerEngine};
use smalltrack::linalg::{set_counters_enabled, LaneWidth, Precision};
use smalltrack::sort::{BatchSort, SortParams};

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("batch_vs_native", &args);
    let smoke = args.smoke;
    let cfg = if smoke { BenchConfig::smoke() } else { BenchConfig::quick() };
    let frames: u32 = if smoke { 120 } else { 300 };
    let params = SortParams { timing: false, ..Default::default() };

    let mut table = Table::new(
        &format!(
            "batch vs native — {frames}-frame single stream{}",
            if smoke { " (smoke mode)" } else { "" }
        ),
        &["trackers", "counters", "engine", "time/frame", "fps", "vs native", "tracks"],
    );

    for &n_obj in &[1u32, 8, 32] {
        let synth =
            generate_sequence(&SynthConfig::mot15(&format!("BVN-{n_obj}"), frames, n_obj, 21));
        let n_frames = synth.sequence.n_frames() as u64;

        // equality gate before any timing: batch must be byte-identical
        // to native on this workload, frame by frame
        {
            let mut native = EngineKind::Native.build(params).expect("native");
            let mut batch = EngineKind::Batch.build(params).expect("batch");
            let mut boxes = Vec::new();
            for frame in &synth.sequence.frames {
                boxes.clear();
                boxes.extend(frame.detections.iter().map(|d| d.bbox));
                let a = native.update(&boxes).to_vec();
                let b = batch.update(&boxes);
                assert_eq!(a.len(), b.len(), "track count diverged (frame {})", frame.index);
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.id, y.id, "ids diverged (frame {})", frame.index);
                    assert_eq!(
                        x.bbox.to_array().map(f64::to_bits),
                        y.bbox.to_array().map(f64::to_bits),
                        "boxes diverged (frame {}, id {})",
                        frame.index,
                        x.id
                    );
                }
            }
        }
        let mut want_tracks: Option<u64> = None;
        for counters_on in [true, false] {
            set_counters_enabled(counters_on);
            let mut native_per_frame = 0.0f64;
            for kind in [EngineKind::Native, EngineKind::Batch] {
                let mut engine = kind.build(params).expect("build engine");
                let mut tracks = 0u64;
                let m = bench(kind.label(), &cfg, n_frames, || {
                    engine.reset();
                    tracks = run_sequence(&mut *engine, &synth.sequence).1;
                });
                // the comparison is meaningless if the engines diverge
                match want_tracks {
                    None => want_tracks = Some(tracks),
                    Some(w) => assert_eq!(tracks, w, "engine {} diverged", kind.label()),
                }
                let per_frame = m.median() / n_frames as f64;
                let rel = if kind == EngineKind::Native {
                    native_per_frame = per_frame;
                    "1.00x".to_string()
                } else {
                    format!("{:.2}x", per_frame / native_per_frame)
                };
                table.row(&[
                    format!("{n_obj}"),
                    if counters_on { "on" } else { "off" }.to_string(),
                    kind.label().to_string(),
                    fmt_duration(per_frame),
                    format!("{:.0}", m.rate()),
                    rel,
                    format!("{tracks}"),
                ]);
            }
        }
        set_counters_enabled(true);
    }
    table.print();
    report.add_table(&table);

    // --- lane-width × precision ablation ---------------------------
    let n_obj: u32 = if smoke { 8 } else { 32 };
    let synth =
        generate_sequence(&SynthConfig::mot15(&format!("LANES-{n_obj}"), frames, n_obj, 21));

    // equality gate before any timing: the f64 tier must be
    // byte-identical to native at EVERY lane width on this workload
    let native_rows = {
        let mut native = EngineKind::Native.build(params).expect("native");
        collect_rows(&mut *native, &synth)
    };
    for width in LaneWidth::ALL {
        let mut e = BatchSort::<f64>::with_lane_width(params, width);
        let rows = collect_rows(&mut e, &synth);
        assert_eq!(
            rows,
            native_rows,
            "f64 lanes ({}) diverged from native — lane width moved a bit",
            width.label()
        );
    }

    set_counters_enabled(true);
    let mut lanes_table = Table::new(
        &format!(
            "lane-width × precision ablation — {n_obj} objects, {frames}-frame single stream{}",
            if smoke { " (smoke mode)" } else { "" }
        ),
        &["precision", "lanes", "time/frame", "fps", "vs scalar-f64", "tracks"],
    );
    let mut scalar_f64 = 0.0f64;
    for width in LaneWidth::ALL {
        time_width::<f64>(&synth, &cfg, params, width, &mut lanes_table, &mut scalar_f64);
    }
    for width in LaneWidth::ALL {
        time_width::<f32>(&synth, &cfg, params, width, &mut lanes_table, &mut scalar_f64);
    }
    lanes_table.print();
    report.add_table(&lanes_table);

    report.finish().unwrap();
    println!("\n'vs native' < 1.00x = the SoA lanes + one-record-per-frame win;");
    println!("'off' rows show the runtime counter tax (compile-time removal:");
    println!("cargo bench --no-default-features removes even the off-branch).");
    println!("ablation: 'vs scalar-f64' < 1.00x = the explicit lane blocks win;");
    println!("f32 rows ride twice the lane width at half the state traffic.");
}

/// One engine pass over a sequence, recording every emitted track as
/// comparable bits (frame, id, box-bit-pattern).
fn collect_rows(engine: &mut dyn TrackerEngine, synth: &SynthSequence) -> Vec<(u32, u64, [u64; 4])> {
    let mut rows = Vec::new();
    let mut boxes = Vec::new();
    for frame in &synth.sequence.frames {
        boxes.clear();
        boxes.extend(frame.detections.iter().map(|d| d.bbox));
        for t in engine.update(&boxes) {
            rows.push((frame.index, t.id, t.bbox.to_array().map(f64::to_bits)));
        }
    }
    rows
}

/// Time one (precision, lane-width) cell of the ablation; the first
/// cell timed (scalar f64) becomes the reference ratio.
fn time_width<P: Precision>(
    synth: &SynthSequence,
    cfg: &BenchConfig,
    params: SortParams,
    width: LaneWidth,
    table: &mut Table,
    scalar_f64: &mut f64,
) where
    BatchSort<P>: TrackerEngine,
{
    let n_frames = synth.sequence.n_frames() as u64;
    let mut engine = BatchSort::<P>::with_lane_width(params, width);
    let mut tracks = 0u64;
    let label = format!("{}-{}", P::TIER.label(), width.label());
    let m = bench(&label, cfg, n_frames, || {
        engine.reset();
        tracks = run_sequence(&mut engine, &synth.sequence).1;
    });
    let per_frame = m.median() / n_frames as f64;
    if *scalar_f64 == 0.0 {
        *scalar_f64 = per_frame;
    }
    table.row(&[
        P::TIER.label().to_string(),
        width.label().to_string(),
        fmt_duration(per_frame),
        format!("{:.0}", m.rate()),
        format!("{:.2}x", per_frame / *scalar_f64),
        format!("{tracks}"),
    ]);
}
