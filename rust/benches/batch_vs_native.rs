//! Batch-vs-native engine bench: per-frame latency, single-stream
//! throughput, and the kernel-counter instrumentation tax.
//!
//! The paper's thesis applied to our own hot loop: at 7×7 matrices the
//! per-tracker *overhead* (pointer chasing across `KalmanBoxTracker`
//! objects, one counter bump per kernel call) rivals the arithmetic.
//! The `batch` engine keeps all trackers in SoA lanes and records one
//! counter event per kernel kind per frame; this bench measures what
//! that buys at 1 / 8 / 32 trackers per frame, with the thread-local
//! counters enabled and runtime-disabled. (Compile with
//! `--no-default-features` to remove the instrumentation entirely —
//! the residual "off" tax below is the branch the feature deletes.)
//!
//! Run modes: `cargo bench --bench batch_vs_native` (full), or append
//! `smoke` (CI) for a seconds-long pass with the same table shape;
//! `--json <path>` writes the table as a machine-readable report.

use smalltrack::benchkit::{bench, fmt_duration, BenchArgs, BenchConfig, BenchReport, Table};
use smalltrack::data::synth::{generate_sequence, SynthConfig};
use smalltrack::engine::{run_sequence, EngineKind, TrackerEngine};
use smalltrack::linalg::set_counters_enabled;
use smalltrack::sort::SortParams;

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("batch_vs_native", &args);
    let smoke = args.smoke;
    let cfg = if smoke { BenchConfig::smoke() } else { BenchConfig::quick() };
    let frames: u32 = if smoke { 120 } else { 300 };
    let params = SortParams { timing: false, ..Default::default() };

    let mut table = Table::new(
        &format!(
            "batch vs native — {frames}-frame single stream{}",
            if smoke { " (smoke mode)" } else { "" }
        ),
        &["trackers", "counters", "engine", "time/frame", "fps", "vs native", "tracks"],
    );

    for &n_obj in &[1u32, 8, 32] {
        let synth =
            generate_sequence(&SynthConfig::mot15(&format!("BVN-{n_obj}"), frames, n_obj, 21));
        let n_frames = synth.sequence.n_frames() as u64;

        // equality gate before any timing: batch must be byte-identical
        // to native on this workload, frame by frame
        {
            let mut native = EngineKind::Native.build(params).expect("native");
            let mut batch = EngineKind::Batch.build(params).expect("batch");
            let mut boxes = Vec::new();
            for frame in &synth.sequence.frames {
                boxes.clear();
                boxes.extend(frame.detections.iter().map(|d| d.bbox));
                let a = native.update(&boxes).to_vec();
                let b = batch.update(&boxes);
                assert_eq!(a.len(), b.len(), "track count diverged (frame {})", frame.index);
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.id, y.id, "ids diverged (frame {})", frame.index);
                    assert_eq!(
                        x.bbox.to_array().map(f64::to_bits),
                        y.bbox.to_array().map(f64::to_bits),
                        "boxes diverged (frame {}, id {})",
                        frame.index,
                        x.id
                    );
                }
            }
        }
        let mut want_tracks: Option<u64> = None;
        for counters_on in [true, false] {
            set_counters_enabled(counters_on);
            let mut native_per_frame = 0.0f64;
            for kind in [EngineKind::Native, EngineKind::Batch] {
                let mut engine = kind.build(params).expect("build engine");
                let mut tracks = 0u64;
                let m = bench(kind.label(), &cfg, n_frames, || {
                    engine.reset();
                    tracks = run_sequence(&mut *engine, &synth.sequence).1;
                });
                // the comparison is meaningless if the engines diverge
                match want_tracks {
                    None => want_tracks = Some(tracks),
                    Some(w) => assert_eq!(tracks, w, "engine {} diverged", kind.label()),
                }
                let per_frame = m.median() / n_frames as f64;
                let rel = if kind == EngineKind::Native {
                    native_per_frame = per_frame;
                    "1.00x".to_string()
                } else {
                    format!("{:.2}x", per_frame / native_per_frame)
                };
                table.row(&[
                    format!("{n_obj}"),
                    if counters_on { "on" } else { "off" }.to_string(),
                    kind.label().to_string(),
                    fmt_duration(per_frame),
                    format!("{:.0}", m.rate()),
                    rel,
                    format!("{tracks}"),
                ]);
            }
        }
        set_counters_enabled(true);
    }
    table.print();
    report.add_table(&table);
    report.finish().unwrap();
    println!("\n'vs native' < 1.00x = the SoA lanes + one-record-per-frame win;");
    println!("'off' rows show the runtime counter tax (compile-time removal:");
    println!("cargo bench --no-default-features removes even the off-branch).");
}
