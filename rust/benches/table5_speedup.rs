//! E5 — the paper's Table V: native (Rust, here; C in the paper) vs the
//! original-style Python implementation, same det.txt inputs.
//!
//! The Python baseline (`python/baseline/sort_python.py`, a faithful
//! abewley/sort port on numpy+scipy) runs as a subprocess — off the
//! request path, exactly like the paper's comparison methodology.
//! Expected shape: 40–100× (paper: 45× on SKX-6140, 106.8× on CLX-8280).

use smalltrack::benchkit::Table;
use smalltrack::coordinator::policy::run_sequence_serial;
use smalltrack::data::mot::write_det_file;
use smalltrack::data::synth::generate_suite;
use smalltrack::sort::SortParams;
use std::time::Instant;

fn main() {
    let suite = generate_suite(7);

    // --- rust native, single core (best of 3)
    let params = SortParams { timing: false, ..Default::default() };
    let mut rust_secs = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        for s in &suite {
            run_sequence_serial(s, params);
        }
        rust_secs = rust_secs.min(t0.elapsed().as_secs_f64());
    }

    // --- python baseline on the same data
    let dir = std::env::temp_dir().join("smalltrack_table5");
    let mut det_files = Vec::new();
    for s in &suite {
        let p = dir.join(&s.sequence.name).join("det").join("det.txt");
        write_det_file(&s.sequence, &p).unwrap();
        det_files.push(p.to_string_lossy().into_owned());
    }
    let baseline = std::path::Path::new("python/baseline/sort_python.py");
    let py_secs = if baseline.exists() {
        let out = std::process::Command::new("python")
            .arg(baseline)
            .args(&det_files)
            .output()
            .expect("spawn python baseline");
        let text = String::from_utf8_lossy(&out.stdout);
        // parse {"seconds": S}
        text.find("\"seconds\": ")
            .and_then(|i| {
                let rest = &text[i + 11..];
                let num: String =
                    rest.chars().take_while(|c| c.is_ascii_digit() || *c == '.').collect();
                num.parse::<f64>().ok()
            })
            .unwrap_or_else(|| panic!("could not parse baseline output: {text}"))
    } else {
        eprintln!("baseline script missing; run from the repo root");
        std::process::exit(1);
    };

    let frames = 5500.0;
    let speedup = py_secs / rust_secs;
    let mut table = Table::new(
        "Table V — speedup w.r.t. the original implementation (5500 frames)",
        &["Machine", "native (ours)", "Python (orig.)", "Speedup"],
    );
    table.row(&[
        "this testbed (1 core)".into(),
        format!("{rust_secs:.3}s ({:.0} fps)", frames / rust_secs),
        format!("{py_secs:.3}s ({:.0} fps)", frames / py_secs),
        format!("{speedup:.1}x"),
    ]);
    table.row(&[
        "paper: Xeon 6140".into(),
        "0.12s (C)".into(),
        "5.4s".into(),
        "45x".into(),
    ]);
    table.row(&[
        "paper: Xeon 8280".into(),
        "0.074s (C)".into(),
        "7.9s".into(),
        "106.8x".into(),
    ]);
    table.print();

    println!("\nshape check: paper reports 44–106x; native must beat python by >10x here");
    assert!(speedup > 10.0, "speedup only {speedup:.1}x");
    let _ = std::fs::remove_dir_all(&dir);
}
