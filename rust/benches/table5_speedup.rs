//! E5 — the paper's Table V: native (Rust, here; C in the paper) vs the
//! original-style Python implementation, same det.txt inputs — plus the
//! other [`TrackerEngine`] backends through the same generic loop.
//!
//! The Python baseline (`python/baseline/sort_python.py`, a faithful
//! abewley/sort port on numpy+scipy) runs as a subprocess — off the
//! request path, exactly like the paper's comparison methodology.
//! Expected shape: 40–100× (paper: 45× on SKX-6140, 106.8× on CLX-8280).

use smalltrack::benchkit::{BenchArgs, BenchReport, Table};
use smalltrack::data::mot::write_det_file;
use smalltrack::data::synth::{generate_suite, SynthSequence};
use smalltrack::engine::{run_sequence, EngineKind, TrackerEngine};
use smalltrack::sort::SortParams;
use std::time::Instant;

/// Best-of-N wall time for one engine over the whole suite, through
/// the trait — every backend is measured by the identical loop.
fn suite_secs(kind: EngineKind, suite: &[SynthSequence], params: SortParams, reps: u32) -> f64 {
    let mut engine = kind.build(params).expect("build engine");
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        for s in suite {
            engine.reset();
            run_sequence(&mut *engine, &s.sequence);
        }
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("table5_speedup", &args);
    let mut suite = generate_suite(7);
    if args.smoke {
        // python-baseline startup dominates a tiny suite; 3 files keep
        // the >10x shape assertion honest while cutting the wall time
        suite.truncate(3);
    }
    let reps: u32 = if args.smoke { 1 } else { 3 };
    let params = SortParams { timing: false, ..Default::default() };
    let frames: f64 = suite.iter().map(|s| s.sequence.n_frames() as f64).sum();

    // --- every engine, same generic loop
    let rust_secs = suite_secs(EngineKind::Native, &suite, params, reps);
    let strong_secs = suite_secs(EngineKind::Strong { threads: 2 }, &suite, params, reps);
    let xla_secs = suite_secs(EngineKind::Xla, &suite, params, reps);

    // --- python baseline on the same data
    let dir = std::env::temp_dir().join("smalltrack_table5");
    let mut det_files = Vec::new();
    for s in &suite {
        let p = dir.join(&s.sequence.name).join("det").join("det.txt");
        write_det_file(&s.sequence, &p).unwrap();
        det_files.push(p.to_string_lossy().into_owned());
    }
    let baseline = std::path::Path::new("python/baseline/sort_python.py");
    let py_secs = if baseline.exists() {
        let out = std::process::Command::new("python")
            .arg(baseline)
            .args(&det_files)
            .output()
            .expect("spawn python baseline");
        let text = String::from_utf8_lossy(&out.stdout);
        // parse {"seconds": S}
        text.find("\"seconds\": ")
            .and_then(|i| {
                let rest = &text[i + 11..];
                let num: String =
                    rest.chars().take_while(|c| c.is_ascii_digit() || *c == '.').collect();
                num.parse::<f64>().ok()
            })
            .unwrap_or_else(|| panic!("could not parse baseline output: {text}"))
    } else {
        eprintln!("baseline script missing; run from the repo root");
        std::process::exit(1);
    };

    let speedup = py_secs / rust_secs;
    let mut table = Table::new(
        &format!("Table V — speedup w.r.t. the original implementation ({frames:.0} frames)"),
        &["Engine / machine", "time", "fps", "speedup vs python"],
    );
    for (label, secs) in [
        ("native (ours, 1 core)", rust_secs),
        ("strong (2 threads)", strong_secs),
        ("xla bank", xla_secs),
        ("python (orig.)", py_secs),
    ] {
        table.row(&[
            label.into(),
            format!("{secs:.3}s"),
            format!("{:.0}", frames / secs),
            format!("{:.1}x", py_secs / secs),
        ]);
    }
    table.row(&[
        "paper: Xeon 6140 (C)".into(),
        "0.12s".into(),
        format!("{:.0}", frames / 0.12),
        "45x".into(),
    ]);
    table.row(&[
        "paper: Xeon 8280 (C)".into(),
        "0.074s".into(),
        format!("{:.0}", frames / 0.074),
        "106.8x".into(),
    ]);
    table.print();
    report.add_table(&table);
    report.finish().unwrap();

    println!("\nshape check: paper reports 44–106x; native must beat python by >10x here");
    assert!(speedup > 10.0, "speedup only {speedup:.1}x");
    let _ = std::fs::remove_dir_all(&dir);
}
