//! E2 — regenerate the paper's Table II: which matrix kernels run
//! inside SORT, how often, and at what arithmetic intensity — counted
//! live by the instrumented linalg layer over the full suite.

use smalltrack::benchkit::{BenchArgs, BenchReport, Table};
use smalltrack::coordinator::policy::run_sequence_serial;
use smalltrack::data::synth::generate_suite;
use smalltrack::linalg::{reset_counters, snapshot, Kernel};
use smalltrack::sort::SortParams;

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("table2_kernels", &args);
    // counting is deterministic, so smoke only shrinks the workload
    let mut suite = generate_suite(7);
    if args.smoke {
        suite.truncate(3);
    }
    reset_counters();
    let mut frames = 0u64;
    for s in &suite {
        frames += run_sequence_serial(
            s,
            SortParams { dense_kernels: true, ..Default::default() },
        )
        .0;
    }
    let counters = snapshot();

    let mut table = Table::new(
        &format!("Table II — frequently used kernels inside SORT (measured, {frames} frames)"),
        &["Kernel", "calls", "calls/frame", "flops", "bytes", "AI (f/B)"],
    );
    for k in Kernel::ALL {
        let s = counters.get(k);
        if s.calls == 0 {
            continue;
        }
        table.row(&[
            k.name().to_string(),
            format!("{}", s.calls),
            format!("{:.1}", s.calls as f64 / frames as f64),
            format!("{:.2e}", s.flops as f64),
            format!("{:.2e}", s.bytes as f64),
            format!("{:.2}", s.ai()),
        ]);
    }
    let t = counters.total();
    table.row(&[
        "TOTAL".into(),
        format!("{}", t.calls),
        format!("{:.1}", t.calls as f64 / frames as f64),
        format!("{:.2e}", t.flops as f64),
        format!("{:.2e}", t.bytes as f64),
        format!("{:.2}", t.ai()),
    ]);
    table.print();
    report.add_table(&table);
    report.finish().unwrap();
    println!("\npaper's Table II sizes: H[4][7] P[7][7] Q[7][7] B[7][4] R[4][4] x[7] u[4], det rows 1x10..13x10");
    println!("all kernels above operate on exactly those shapes (const-generic, see rust/src/linalg/)");
    assert!(counters.get(Kernel::Gemm).calls > 0);
    assert!(counters.get(Kernel::Inverse).calls > 0);
    assert!(counters.get(Kernel::Hungarian).calls > 0);
}
