//! Microbenchmark: the Hungarian solver over the paper's problem sizes
//! (2..13 objects — Table I's max is 13) plus the greedy baseline and
//! the original's permutation fast-path.

use smalltrack::benchkit::{bench, fmt_duration, BenchArgs, BenchReport, Table};
use smalltrack::linalg::set_counters_enabled;
use smalltrack::prng::Rng;
use smalltrack::sort::greedy::greedy_max_score;
use smalltrack::sort::hungarian::{hungarian_min_cost, HungarianScratch};

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("micro_hungarian", &args);
    set_counters_enabled(false);
    let cfg = args.config();
    let mut rng = Rng::new(0xBEEF);

    let mut table = Table::new(
        "micro — assignment solve at SORT sizes (cost = -IoU in [-1,0])",
        &["n x n", "hungarian", "greedy", "ratio"],
    );
    for n in [2usize, 4, 7, 10, 13, 16] {
        let cost: Vec<f64> = (0..n * n).map(|_| -rng.uniform()).collect();
        let score: Vec<f64> = cost.iter().map(|v| -v).collect();
        let mut scratch = HungarianScratch::default();
        let h = bench(&format!("hungarian {n}"), &cfg, 1, || {
            std::hint::black_box(hungarian_min_cost(&cost, n, n, &mut scratch))
        });
        let g = bench(&format!("greedy {n}"), &cfg, 1, || {
            std::hint::black_box(greedy_max_score(&score, n, n, 0.0))
        });
        table.row(&[
            format!("{n}x{n}"),
            fmt_duration(h.median()),
            fmt_duration(g.median()),
            format!("{:.1}x", h.median() / g.median()),
        ]);
    }
    table.print();
    report.add_table(&table);
    report.finish().unwrap();
    println!("\neven at 13x13 (Table I max) the optimal solve is ~microseconds —");
    println!("assignment is 22% of frame time only because the frame itself is ~20us.");
    set_counters_enabled(true);
}
