//! E7 — the paper's Fig 4: strong vs weak scaling with the input files
//! replicated 7× (77 files), on the Xeon 8280 profile — plus the
//! measured shard-scheduler counterpart (pinned vs stealing) on the
//! same 77-file workload, the deployment form of the "weak" column.

use smalltrack::benchkit::{BenchArgs, BenchReport, Table};
use smalltrack::coordinator::scheduler::{run_shards, SchedulerConfig, ShardPolicy};
use smalltrack::data::replicate::replicate_suite;
use smalltrack::simcore::{calibrate_workload, simulate, MachineProfile, SimPolicy};
use smalltrack::sort::SortParams;

fn main() {
    let args = BenchArgs::from_env();
    let mut report = BenchReport::new("fig4_strong_vs_weak", &args);
    // 7x replicated inputs, as in the paper (3x in smoke mode — the
    // shape assertions only need heterogeneous multi-file input)
    let replicas: u32 = if args.smoke { 3 } else { 7 };
    let suite = replicate_suite(7, replicas);
    assert_eq!(suite.len(), 11 * replicas as usize);

    // calibrate on a subset (the 11 base sequences) — replicas share
    // the cost model; then extend the workload to all 77
    let w = calibrate_workload(&suite, 1);
    println!(
        "calibrated {} files / {} frames; single-core anchor {:.0} FPS",
        w.seqs.len(),
        w.total_frames(),
        w.single_core_fps()
    );

    let m = MachineProfile::clx8280();
    let mut table = Table::new(
        &format!(
            "Fig 4 — strong vs weak scaling, {} files, CLX-8280 profile (FPS)",
            suite.len()
        ),
        &["Cores", "Strong", "Weak", "weak/strong"],
    );
    let mut series = Vec::new();
    for p in [1usize, 14, 28, 56, 112] {
        let s = simulate(&w, &m, SimPolicy::Strong { threads: p }).fps_paper_metric;
        let wk = simulate(&w, &m, SimPolicy::Weak { cores: p }).fps_paper_metric;
        series.push((p, s, wk));
        table.row(&[
            format!("{p}"),
            format!("{s:.0}"),
            format!("{wk:.0}"),
            format!("{:.2}x", wk / s),
        ]);
    }
    table.print();
    report.add_table(&table);

    // text chart
    println!("\nFig 4 (text form): FPS vs cores");
    let max_fps = series.iter().map(|(_, s, w)| s.max(*w)).fold(0.0f64, f64::max);
    for (p, s, wk) in &series {
        let sb = "S".repeat((s / max_fps * 40.0).round() as usize);
        let wb = "W".repeat((wk / max_fps * 40.0).round() as usize);
        println!("  p={p:>3} strong |{sb}");
        println!("        weak   |{wb}");
    }

    println!("\nshape checks (paper: weak > strong at every multi-core point):");
    for (p, s, wk) in &series[1..] {
        assert!(wk > s, "weak must beat strong at p={p}");
    }
    // weak sustains: last point within 25% of first multi-core point
    let w14 = series[1].2;
    let w112 = series[4].2;
    assert!(w112 / w14 > 0.75, "weak scaling collapsed: {w14} -> {w112}");

    // measured counterpart: the shard scheduler on the same 77 files.
    // Replication preserves the heterogeneous 71..1000-frame mix, so
    // pinned shards finish ragged and stealing reclaims the idle tail.
    let params = SortParams { timing: false, ..Default::default() };
    let mut measured = Table::new(
        &format!("Fig 4 (measured) — shard scheduler on {} files (FPS, wall-clock)", suite.len()),
        &["Workers", "Pinned", "Stealing", "stolen"],
    );
    let workers: &[usize] = if args.smoke { &[1, 2] } else { &[1, 2, 4] };
    let reps = if args.smoke { 1 } else { 2 };
    let mut anchor: Option<u64> = None;
    for &p in workers {
        let mut fps = [0.0f64; 2];
        let mut stolen = 0u64;
        for (i, policy) in [ShardPolicy::Pinned, ShardPolicy::Stealing].iter().enumerate() {
            for _ in 0..reps {
                let r = run_shards(
                    &suite,
                    SchedulerConfig {
                        workers: p,
                        shard_policy: *policy,
                        sort_params: params,
                        queue_capacity: 128,
                        ..Default::default()
                    },
                );
                let a = *anchor.get_or_insert(r.tracks_out);
                assert_eq!(r.tracks_out, a, "scheduler output drifted at p={p}");
                if r.fps() > fps[i] {
                    fps[i] = r.fps();
                    if *policy == ShardPolicy::Stealing {
                        stolen = r.stolen;
                    }
                }
            }
        }
        measured.row(&[
            format!("{p}"),
            format!("{:.0}", fps[0]),
            format!("{:.0}", fps[1]),
            format!("{stolen}"),
        ]);
    }
    measured.print();
    report.add_table(&measured);
    report.finish().unwrap();
}
