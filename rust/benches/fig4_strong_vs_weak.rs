//! E7 — the paper's Fig 4: strong vs weak scaling with the input files
//! replicated 7× (77 files), on the Xeon 8280 profile.

use smalltrack::benchkit::Table;
use smalltrack::data::replicate::replicate_suite;
use smalltrack::simcore::{calibrate_workload, simulate, MachineProfile, SimPolicy};

fn main() {
    // 7x replicated inputs, as in the paper
    let suite = replicate_suite(7, 7);
    assert_eq!(suite.len(), 77);

    // calibrate on a subset (the 11 base sequences) — replicas share
    // the cost model; then extend the workload to all 77
    let w = calibrate_workload(&suite, 1);
    println!(
        "calibrated {} files / {} frames; single-core anchor {:.0} FPS",
        w.seqs.len(),
        w.total_frames(),
        w.single_core_fps()
    );

    let m = MachineProfile::clx8280();
    let mut table = Table::new(
        "Fig 4 — strong vs weak scaling, 77 files, CLX-8280 profile (FPS)",
        &["Cores", "Strong", "Weak", "weak/strong"],
    );
    let mut series = Vec::new();
    for p in [1usize, 14, 28, 56, 112] {
        let s = simulate(&w, &m, SimPolicy::Strong { threads: p }).fps_paper_metric;
        let wk = simulate(&w, &m, SimPolicy::Weak { cores: p }).fps_paper_metric;
        series.push((p, s, wk));
        table.row(&[
            format!("{p}"),
            format!("{s:.0}"),
            format!("{wk:.0}"),
            format!("{:.2}x", wk / s),
        ]);
    }
    table.print();

    // text chart
    println!("\nFig 4 (text form): FPS vs cores");
    let max_fps = series.iter().map(|(_, s, w)| s.max(*w)).fold(0.0f64, f64::max);
    for (p, s, wk) in &series {
        let sb = "S".repeat((s / max_fps * 40.0).round() as usize);
        let wb = "W".repeat((wk / max_fps * 40.0).round() as usize);
        println!("  p={p:>3} strong |{sb}");
        println!("        weak   |{wb}");
    }

    println!("\nshape checks (paper: weak > strong at every multi-core point):");
    for (p, s, wk) in &series[1..] {
        assert!(wk > s, "weak must beat strong at p={p}");
    }
    // weak sustains: last point within 25% of first multi-core point
    let w14 = series[1].2;
    let w112 = series[4].2;
    assert!(w112 / w14 > 0.75, "weak scaling collapsed: {w14} -> {w112}");
}
