//! Wire front-door integration: the recovery contract end to end.
//!
//! The contract under test (the robustness tentpole's acceptance
//! criteria): after mid-stream disconnects and corrupted frames, the
//! reconnect-and-replay protocol must deliver tracks **bit-identical**
//! (`f64::to_bits`) to an in-process run of the same engine, and the
//! client's frame-conservation ledger must balance
//! (`frames_sent == frames_acked + rejected + in_flight_at_close`).
//! Covered at three levels: an explicit deterministic fault schedule
//! with three mid-stream cuts plus corruption in both directions, the
//! seeded `FaultPlan::aggressive` schedule over multiple streams, and
//! the `netload` / `track-serve` CLI binaries over real loopback TCP.

use smalltrack::coordinator::faults::{DirectionPlan, FaultPlan};
use smalltrack::coordinator::net::{
    approx_upstream_bytes, detection_frames, netload_run, NetloadOptions,
};
use smalltrack::data::synth::{generate_sequence, SynthConfig};
use smalltrack::engine::EngineKind;
use smalltrack::sort::Bbox;
use std::io::BufRead;
use std::process::{Child, Command, Stdio};

fn synth_stream(name: &str, frames: u32, objects: u32, seed: u64) -> Vec<Vec<Bbox>> {
    let cfg = SynthConfig::mot15(name, frames, objects, seed);
    detection_frames(&generate_sequence(&cfg).sequence)
}

#[test]
fn three_cuts_and_corruption_recover_bit_identically() {
    // One stream, and a hand-placed schedule instead of the seeded
    // one, so every fault is *mid-stream* by construction: three cuts
    // at 25/50/75% of the upstream byte budget (the handshake and the
    // tail are clear of them) plus corrupted bytes in both directions.
    let frames = synth_stream("wire-int-cuts", 120, 6, 11);
    let approx = approx_upstream_bytes(&frames);
    let plan = FaultPlan {
        to_server: DirectionPlan {
            corrupt_at: vec![approx * 35 / 100, approx * 65 / 100, approx * 85 / 100],
            cut_at: vec![approx / 4, approx / 2, approx * 3 / 4],
            delay_at: vec![],
        },
        // acks + track rows make the downstream stream the bigger one;
        // offsets sized accordingly
        to_client: DirectionPlan {
            corrupt_at: vec![approx / 2, approx],
            cut_at: vec![],
            delay_at: vec![],
        },
        ..FaultPlan::default()
    };
    let mut opts = NetloadOptions::new(EngineKind::Batch);
    opts.seed = 11;
    opts.checkpoint_every = 8;
    opts.faults = Some(plan);
    let out = netload_run(opts, std::slice::from_ref(&frames)).expect("netload run");

    // the acceptance criteria: bit-identity and a conserved ledger
    assert!(out.bit_identical, "tracks diverged from the in-process reference run");
    let l = &out.ledger;
    assert!(l.conserves(), "{l:?}");
    // every frame eventually got through — faults cost retries, never
    // frames (the per-frame retry budget is far above this schedule)
    assert_eq!(l.frames_sent, 120, "{l:?}");
    assert_eq!(l.frames_acked, 120, "{l:?}");
    assert_eq!(l.rejected, 0, "{l:?}");
    assert_eq!(l.in_flight_at_close, 0, "{l:?}");
    // three mid-stream cuts force at least three reconnect+resume
    // cycles (corruption-poisoned connections add more)
    assert!(l.reconnects >= 3, "expected >= 3 reconnects, got {}", l.reconnects);
    assert!(l.resent > 0, "recovery must have replayed unacked frames over the wire");

    let sc = out.server_counters.as_ref().expect("self-served run reports server counters");
    assert!(sc.dirty_disconnects >= 3, "{sc:?}");
    assert!(sc.reconnects >= 3, "{sc:?}");
    assert!(sc.replays >= 1, "resume must replay frames past the last checkpoint: {sc:?}");
    assert!(sc.rejected_frames >= 1, "corrupted upstream bytes must be rejected: {sc:?}");
    assert_eq!(sc.sessions_opened, 1, "one logical session across every reconnect: {sc:?}");
}

#[test]
fn aggressive_seeded_faults_over_multiple_streams_conserve_and_match() {
    let streams: Vec<Vec<Vec<Bbox>>> = (0..3)
        .map(|i| synth_stream(&format!("wire-int-aggr{i}"), 60, 4 + i, 23 + i as u64))
        .collect();
    let span: u64 = streams.iter().map(|s| approx_upstream_bytes(s)).sum();
    let mut opts = NetloadOptions::new(EngineKind::Batch);
    opts.seed = 23;
    opts.checkpoint_every = 8;
    opts.server.service.workers = 2;
    opts.faults = Some(FaultPlan::aggressive(23, span, 4));
    let out = netload_run(opts, &streams).expect("netload run");

    assert!(out.bit_identical, "tracks diverged under the aggressive schedule");
    assert!(out.ledger.conserves(), "{:?}", out.ledger);
    assert_eq!(out.ledger.frames_sent, 180, "{:?}", out.ledger);
    assert_eq!(out.ledger.frames_acked, 180, "{:?}", out.ledger);
    for (i, l) in out.per_stream.iter().enumerate() {
        assert!(l.conserves(), "stream {i}: {l:?}");
        assert_eq!(l.frames_sent, 60, "stream {i}: {l:?}");
    }
    assert!(out.ledger.reconnects >= 1, "{:?}", out.ledger);
    let sc = out.server_counters.as_ref().unwrap();
    assert_eq!(sc.sessions_opened, 3, "one logical session per stream: {sc:?}");
    assert_eq!(out.rows.len(), 3);
    assert!(out.latency.count() > 0, "push-to-poll latency must be sampled");
}

// --- CLI level -----------------------------------------------------------

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_smalltrack"))
}

#[test]
fn netload_cli_enforces_the_contract_and_writes_the_report() {
    let dir = std::env::temp_dir().join(format!("smalltrack_wire_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let json = dir.join("wire.json");
    let out = bin()
        .args(["netload", "--streams", "2", "--frames", "50", "--engine", "batch"])
        .args(["--faults", "aggressive", "--cuts", "3", "--seed", "9", "--json"])
        .arg(&json)
        .output()
        .expect("spawn netload");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "netload failed:\n{stdout}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("OK: ledger conserves"), "{stdout}");

    let report = smalltrack::data::json::parse(&std::fs::read_to_string(&json).unwrap())
        .expect("wire report is valid JSON");
    assert_eq!(report.req("streams").num(), 2.0);
    assert_eq!(report.req("frames_per_stream").num(), 50.0);
    assert_eq!(report.req("faulted").as_bool(), Some(true));
    assert_eq!(report.req("bit_identical").as_bool(), Some(true));
    assert_eq!(report.req("conserves").as_bool(), Some(true));
    assert_eq!(report.req("frames_sent").num(), 100.0);
    assert_eq!(report.req("frames_acked").num(), 100.0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kills the serve child even when an assert unwinds.
struct KillOnDrop(Child);
impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn netload_cli_reaches_a_track_serve_process_over_loopback() {
    // real two-process deployment: `track-serve` on an OS-assigned
    // port, `netload --addr` pointed at it
    let child = bin()
        .args(["track-serve", "--addr", "127.0.0.1:0", "--workers", "2"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn track-serve");
    let mut guard = KillOnDrop(child);
    let stdout = guard.0.stdout.take().expect("piped stdout");
    let mut lines = std::io::BufReader::new(stdout).lines();
    let banner = lines
        .next()
        .expect("track-serve printed nothing")
        .expect("read track-serve banner");
    // "track-serve listening on 127.0.0.1:PORT (...)"
    let addr = banner
        .split_whitespace()
        .find(|w| w.starts_with("127.0.0.1:"))
        .unwrap_or_else(|| panic!("no address in banner: {banner}"))
        .to_string();
    assert_ne!(addr, "127.0.0.1:0", "server must report the real port");

    let out = bin()
        .args(["netload", "--streams", "2", "--frames", "40", "--engine", "batch", "--addr"])
        .arg(&addr)
        .output()
        .expect("spawn netload");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "netload vs track-serve failed:\n{stdout}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("OK: ledger conserves"), "{stdout}");
}
