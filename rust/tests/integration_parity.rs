//! Cross-layer parity: the Rust Kalman filter vs the JAX oracle.
//!
//! `make artifacts` exports `artifacts/parity.json` — a golden
//! trajectory computed by `python/compile/kernels/ref.py` (the same
//! oracle the Pallas kernels are tested against). These tests replay it
//! through the native Rust filter; agreement here means L1 (Pallas), L2
//! (JAX) and L3 (Rust) all implement the same arithmetic.

use smalltrack::data::json::{parse_file, Value};
use smalltrack::linalg::{Mat, Mat4, Mat4x7, Mat7};
use smalltrack::sort::kalman::{CovarianceForm, KalmanState, SortConstants};
use smalltrack::sort::Bbox;
use std::path::PathBuf;

fn parity_path() -> Option<PathBuf> {
    let p = smalltrack::runtime::artifacts_dir().join("parity.json");
    p.exists().then_some(p)
}

fn mat7_from(v: &Value) -> Mat7 {
    let rows = v.f64_mat();
    let flat: Vec<f64> = rows.into_iter().flatten().collect();
    Mat::from_slice(&flat)
}

#[test]
fn constants_match_oracle() {
    let Some(path) = parity_path() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let parity = parse_file(&path).unwrap();
    let c = parity.req("constants");
    let ours = SortConstants::sort_defaults();

    let f = mat7_from(c.req("F"));
    assert!(ours.f.max_abs_diff(&f) == 0.0, "F differs");
    let q = mat7_from(c.req("Q"));
    assert!(ours.q.max_abs_diff(&q) < 1e-15, "Q differs");
    let p0 = mat7_from(c.req("P0"));
    assert!(ours.p0.max_abs_diff(&p0) == 0.0, "P0 differs");

    let h_rows = c.req("H").f64_mat();
    let h = Mat4x7::from_slice(&h_rows.into_iter().flatten().collect::<Vec<_>>());
    assert!(ours.h.max_abs_diff(&h) == 0.0, "H differs");
    let r_rows = c.req("R").f64_mat();
    let r = Mat4::from_slice(&r_rows.into_iter().flatten().collect::<Vec<_>>());
    assert!(ours.r.max_abs_diff(&r) == 0.0, "R differs");
}

#[test]
fn kalman_trajectory_matches_oracle() {
    let Some(path) = parity_path() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let parity = parse_file(&path).unwrap();
    let consts = SortConstants::sort_defaults();

    // seed three trackers from frame 0 of the scenario
    let frames = parity.req("frames").arr();
    let frame0 = frames[0].f64_mat();
    let mut states: Vec<KalmanState> = frame0
        .iter()
        .map(|b| {
            let bbox = Bbox::new(b[0], b[1], b[2], b[3]);
            KalmanState::from_measurement(&bbox.to_z(), &consts)
        })
        .collect();

    for step in parity.req("steps").arr() {
        // predict and compare against x_pred / p_pred_diag
        let x_pred = step.req("x_pred").f64_mat();
        let p_pred_diag = step.req("p_pred_diag").f64_mat();
        for (i, s) in states.iter_mut().enumerate() {
            s.predict(&consts);
            for k in 0..7 {
                assert!(
                    (s.x[k] - x_pred[i][k]).abs() < 1e-9,
                    "frame {} trk {i} x[{k}]: {} vs {}",
                    step.req("frame").num(),
                    s.x[k],
                    x_pred[i][k]
                );
            }
            let diag = s.p.diagonal();
            for k in 0..7 {
                let want = p_pred_diag[i][k];
                assert!(
                    (diag[k] - want).abs() < 1e-6 * want.abs().max(1.0),
                    "P diag mismatch trk {i} [{k}]: {} vs {want}",
                    diag[k]
                );
            }
        }
        // update with z and compare x_post / full p_post
        let z = step.req("z").f64_mat();
        let x_post = step.req("x_post").f64_mat();
        let p_post = step.req("p_post").arr();
        for (i, s) in states.iter_mut().enumerate() {
            let zi = [z[i][0], z[i][1], z[i][2], z[i][3]];
            assert!(s.update(&zi, &consts, CovarianceForm::Joseph));
            for k in 0..7 {
                assert!(
                    (s.x[k] - x_post[i][k]).abs() < 1e-9,
                    "post x mismatch trk {i} [{k}]"
                );
            }
            let want_p = mat7_from(&p_post[i]);
            assert!(
                s.p.max_abs_diff(&want_p) < 1e-8,
                "post P mismatch trk {i}: {}",
                s.p.max_abs_diff(&want_p)
            );
        }
    }
}

#[test]
fn iou_matrix_matches_oracle() {
    let Some(path) = parity_path() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let parity = parse_file(&path).unwrap();
    let case = parity.req("iou_case");
    let dets: Vec<Bbox> = case
        .req("dets")
        .f64_mat()
        .iter()
        .map(|b| Bbox::new(b[0], b[1], b[2], b[3]))
        .collect();
    let boxes: Vec<Bbox> = case
        .req("boxes")
        .f64_mat()
        .iter()
        .map(|b| Bbox::new(b[0], b[1], b[2], b[3]))
        .collect();
    let want = case.req("iou").f64_mat();
    let got = smalltrack::sort::iou::iou_matrix(&dets, &boxes);
    for d in 0..dets.len() {
        for t in 0..boxes.len() {
            assert!(
                (got[d * boxes.len() + t] - want[d][t]).abs() < 1e-12,
                "iou[{d}][{t}]"
            );
        }
    }
}
