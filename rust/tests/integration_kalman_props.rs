//! Property tests on the Kalman filter: the structure-aware fast path
//! vs the dense formulation on randomized states, plus filter
//! invariants under randomized measurement streams.

use smalltrack::linalg::Mat;
use smalltrack::proptest_lite::{ensure, run_named, Config};
use smalltrack::sort::kalman::{is_symmetric_psd, CovarianceForm, KalmanState, SortConstants};

fn random_state(rng: &mut smalltrack::prng::Rng, consts: &SortConstants) -> KalmanState {
    let z = [
        rng.range(0.0, 1920.0),
        rng.range(0.0, 1080.0),
        rng.range(50.0, 40000.0),
        rng.range(0.2, 5.0),
    ];
    let mut s = KalmanState::from_measurement(&z, consts);
    s.x[4] = rng.range(-10.0, 10.0);
    s.x[5] = rng.range(-10.0, 10.0);
    s.x[6] = rng.range(-100.0, 100.0);
    // random SPD covariance: B B' + 2I, scaled
    let mut b = Mat::<7, 7>::zeros();
    for r in 0..7 {
        for c in 0..7 {
            b[(r, c)] = rng.normal();
        }
    }
    s.p = b.matmul_nt(&b).add(&Mat::eye().scale(2.0)).scale(rng.range(0.5, 20.0));
    s
}

#[test]
fn prop_fast_predict_equals_dense() {
    let consts = SortConstants::sort_defaults();
    run_named(
        "predict-fast-vs-dense",
        Config { cases: 300, seed: 0xFA57 },
        |rng| random_state(rng, &consts),
        |s0| {
            let mut fast = *s0;
            let mut dense = *s0;
            fast.predict(&consts);
            dense.predict_dense(&consts);
            for r in 0..7 {
                ensure(
                    (fast.x[r] - dense.x[r]).abs() < 1e-9 * dense.x[r].abs().max(1.0),
                    format!("x[{r}]: {} vs {}", fast.x[r], dense.x[r]),
                )?;
                for c in 0..7 {
                    ensure(
                        (fast.p[(r, c)] - dense.p[(r, c)]).abs()
                            < 1e-9 * dense.p[(r, c)].abs().max(1.0),
                        format!("P[{r}][{c}]"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fast_update_equals_dense() {
    let consts = SortConstants::sort_defaults();
    run_named(
        "update-fast-vs-dense",
        Config { cases: 300, seed: 0x0DDB },
        |rng| {
            let s = random_state(rng, &consts);
            let z = [
                rng.range(0.0, 1920.0),
                rng.range(0.0, 1080.0),
                rng.range(50.0, 40000.0),
                rng.range(0.2, 5.0),
            ];
            let form = if rng.chance(0.5) { CovarianceForm::Joseph } else { CovarianceForm::Simple };
            (s, z, form)
        },
        |(s0, z, form)| {
            let mut fast = *s0;
            let mut dense = *s0;
            let ok_f = fast.update(z, &consts, *form);
            let ok_d = dense.update_dense(z, &consts, *form);
            ensure(ok_f == ok_d, "SPD acceptance must agree")?;
            if !ok_f {
                return Ok(());
            }
            for r in 0..7 {
                ensure(
                    (fast.x[r] - dense.x[r]).abs() < 1e-7 * dense.x[r].abs().max(1.0),
                    format!("x[{r}]: {} vs {}", fast.x[r], dense.x[r]),
                )?;
                for c in 0..7 {
                    ensure(
                        (fast.p[(r, c)] - dense.p[(r, c)]).abs()
                            < 1e-7 * dense.p[(r, c)].abs().max(1.0),
                        format!("P[{r}][{c}]: {} vs {}", fast.p[(r, c)], dense.p[(r, c)]),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_joseph_update_preserves_spd() {
    let consts = SortConstants::sort_defaults();
    run_named(
        "joseph-preserves-spd",
        Config { cases: 200, seed: 0x5BD },
        |rng| {
            let s = random_state(rng, &consts);
            let z = [
                rng.range(0.0, 1920.0),
                rng.range(0.0, 1080.0),
                rng.range(50.0, 40000.0),
                rng.range(0.2, 5.0),
            ];
            (s, z)
        },
        |(s0, z)| {
            let mut s = *s0;
            if !s.update(z, &consts, CovarianceForm::Joseph) {
                return Ok(()); // rejected non-SPD input
            }
            ensure(is_symmetric_psd(&s.p, 1e-6), "P lost SPD after Joseph update")
        },
    );
}

#[test]
fn prop_update_is_contraction_on_observed_block() {
    // folding in a measurement never increases the observed variance
    let consts = SortConstants::sort_defaults();
    run_named(
        "update-contracts-observed-variance",
        Config { cases: 200, seed: 0xC0 },
        |rng| {
            let s = random_state(rng, &consts);
            let z = [
                rng.range(0.0, 1920.0),
                rng.range(0.0, 1080.0),
                rng.range(50.0, 40000.0),
                rng.range(0.2, 5.0),
            ];
            (s, z)
        },
        |(s0, z)| {
            let mut s = *s0;
            let before = s.p.diagonal();
            if !s.update(z, &consts, CovarianceForm::Joseph) {
                return Ok(());
            }
            let after = s.p.diagonal();
            for i in 0..4 {
                ensure(
                    after[i] <= before[i] * (1.0 + 1e-9),
                    format!("var[{i}] grew: {} -> {}", before[i], after[i]),
                )?;
            }
            Ok(())
        },
    );
}
