//! Scenario-lab integration: the CLI `lab run → gate` path end to end,
//! report schema round-trip through real files, gate pass/fail on
//! synthetic deltas, determinism of scenario generation, and the
//! checked-in CI baseline's consistency with the smoke grid.

use smalltrack::lab::{LabReport, ScenarioAxes};
use std::path::{Path, PathBuf};
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_smalltrack"))
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("smalltrack_lab_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Path to the checked-in floor baseline (tests run from the repo root).
fn baseline_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join("bench_baseline.json")
}

#[test]
fn checked_in_baseline_matches_the_smoke_grid() {
    // the CI gate compares cells by id — if the smoke grid and the
    // baseline drift apart, the gate would fail on MISSING cells, so
    // pin their agreement here (regenerate the baseline when this
    // fires: `cargo run --release -- lab run --smoke --json
    // artifacts/bench_baseline.json`)
    let base = LabReport::load(&baseline_path()).expect("baseline parses");
    let want: Vec<String> = ScenarioAxes::smoke_cells().iter().map(|c| c.id()).collect();
    let got: Vec<String> = base.cells.iter().map(|c| c.id.clone()).collect();
    assert_eq!(got, want, "baseline cells drifted from ScenarioAxes::smoke_cells()");
    assert!(base.manifest.smoke);
    assert_eq!(base.manifest.tool, "smalltrack-lab");
    // exactly the overload cell carries an SLO block, exactly the
    // wire cell carries a wire block, and exactly the real-input cell
    // carries an ingest block
    for c in &base.cells {
        assert_eq!(c.slo.is_some(), c.id.ends_with("-a2x"), "{}", c.id);
        assert_eq!(c.wire.is_some(), c.id.ends_with("-wire"), "{}", c.id);
        assert_eq!(c.ingest.is_some(), c.id == "batch-ingest-tiny", "{}", c.id);
    }
    let ingest = base.cells.iter().find(|c| c.ingest.is_some()).expect("ingest cell");
    let block = ingest.ingest.as_ref().unwrap();
    assert_eq!(block.format, "mot");
    assert_eq!((block.frames, block.detections, block.gt_tracks), (60, 322, 6));
    assert_eq!(block.warnings, 0, "fixtures must validate clean");
}

#[test]
fn scenario_generation_is_deterministic() {
    for cell in ScenarioAxes::smoke_cells() {
        let a = cell.sequences();
        let b = cell.sequences();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sequence.n_frames(), y.sequence.n_frames());
            for (fx, fy) in x.sequence.frames.iter().zip(&y.sequence.frames) {
                assert_eq!(fx.detections.len(), fy.detections.len(), "{}", cell.id());
                for (dx, dy) in fx.detections.iter().zip(&fy.detections) {
                    assert_eq!(dx.bbox, dy.bbox, "{}", cell.id());
                }
            }
        }
    }
}

#[test]
fn lab_run_smoke_emits_schema_valid_report_and_gates_against_baseline() {
    let dir = tmpdir("run");
    let out = dir.join("bench_smoke.json");

    // --- lab run --smoke --json <path>
    let run = bin()
        .args(["lab", "run", "--smoke", "--json"])
        .arg(&out)
        .output()
        .expect("spawn lab run");
    assert!(run.status.success(), "lab run failed: {}", String::from_utf8_lossy(&run.stderr));
    let report = LabReport::load(&out).expect("schema-valid report");

    // manifest + one cell per smoke scenario (grid + overload), in order
    assert!(report.manifest.smoke);
    let want: Vec<String> = ScenarioAxes::smoke_cells().iter().map(|c| c.id()).collect();
    let got: Vec<String> = report.cells.iter().map(|c| c.id.clone()).collect();
    assert_eq!(got, want);
    assert!(report.manifest.features.iter().any(|(k, _)| k == "counters"));

    for c in &report.cells {
        assert!(c.fps.median > 0.0, "{}: no throughput measured", c.id);
        assert!(c.quality.n_gt > 0, "{}: no ground truth scored", c.id);
        assert!(c.quality.mota > 0.05, "{}: implausible MOTA {}", c.id, c.quality.mota);
        assert_eq!(c.total_frames, c.frames * c.streams as u64);
        #[cfg(feature = "counters")]
        assert!(c.counters.total_calls > 0, "{}: no kernels counted", c.id);
    }

    // the overload cell measured a real SLO: a positive deadline, a
    // conserved frame ledger, and every frame either delivered or in
    // one of the two drop buckets
    let slo_cells: Vec<_> = report.cells.iter().filter(|c| c.slo.is_some()).collect();
    assert_eq!(slo_cells.len(), 1, "smoke suite carries exactly one overload cell");
    let (c, s) = (slo_cells[0], slo_cells[0].slo.unwrap());
    assert!(s.admission > 1.0 && s.sustainable_fps > 0.0 && s.deadline_ms > 0.0);
    assert_eq!(s.delivered + s.dropped_queue + s.dropped_deadline, c.total_frames, "{}", c.id);
    assert!((0.0..=1.0).contains(&s.deadline_hit_ratio), "{}", c.id);

    // the wire cell ran the real TCP loopback path: a conserved frame
    // ledger, every frame acknowledged, and tracks bit-identical to
    // the in-process reference run
    let wire_cells: Vec<_> = report.cells.iter().filter(|c| c.wire.is_some()).collect();
    assert_eq!(wire_cells.len(), 1, "smoke suite carries exactly one wire cell");
    let (c, w) = (wire_cells[0], wire_cells[0].wire.unwrap());
    assert!(w.conserves(), "{}: {w:?}", c.id);
    assert_eq!(w.frames_sent, c.total_frames, "{}", c.id);
    assert_eq!(w.frames_acked, c.total_frames, "{}", c.id);
    assert!(w.bit_identical, "{}: wire tracks diverged from the in-process run", c.id);
    assert!(w.sessions_per_sec > 0.0 && w.p99_ms >= w.p50_ms, "{}", c.id);

    // the real-input cell parsed the checked-in fixtures through the
    // ingest IR and scored against their ground truth
    let ingest_cells: Vec<_> = report.cells.iter().filter(|c| c.ingest.is_some()).collect();
    assert_eq!(ingest_cells.len(), 1, "smoke suite carries exactly one ingest cell");
    let (c, i) = (ingest_cells[0], ingest_cells[0].ingest.as_ref().unwrap());
    assert_eq!(c.id, "batch-ingest-tiny");
    assert_eq!(i.format, "mot", "{}", c.id);
    assert_eq!((i.frames, i.detections, i.gt_tracks), (60, 322, 6), "{}", c.id);
    assert_eq!(i.warnings, 0, "{}: fixtures must validate clean", c.id);
    assert_eq!(c.frames, i.frames, "{}: cell frames come from the fixture", c.id);
    assert!(c.quality.mota > 0.2, "{}: implausible fixture MOTA {}", c.id, c.quality.mota);

    // --- lab gate <checked-in baseline> <fresh run> passes (floor
    // baseline: any healthy build clears it at the default margins)
    let gate = bin()
        .args(["lab", "gate"])
        .arg(baseline_path())
        .arg(&out)
        .output()
        .expect("spawn lab gate");
    let stdout = String::from_utf8_lossy(&gate.stdout);
    assert!(
        gate.status.success(),
        "gate failed against the floor baseline:\n{stdout}\n{}",
        String::from_utf8_lossy(&gate.stderr)
    );
    assert!(stdout.contains("GATE PASS"), "{stdout}");

    // --- lab compare prints the same table without gating
    let cmp = bin()
        .args(["lab", "compare"])
        .arg(baseline_path())
        .arg(&out)
        .output()
        .expect("spawn lab compare");
    assert!(cmp.status.success());
    assert!(String::from_utf8_lossy(&cmp.stdout).contains("lab compare"));

    let _ = std::fs::remove_dir_all(&dir);
}

/// Doctor one cell of the baseline and check the gate's verdicts on
/// the synthetic delta.
fn doctored(name: &str, mutate: impl Fn(&mut LabReport)) -> (PathBuf, PathBuf, PathBuf) {
    let dir = tmpdir(name);
    let base = LabReport::load(&baseline_path()).unwrap();
    let mut cur = base.clone();
    mutate(&mut cur);
    let base_path = dir.join("base.json");
    let cur_path = dir.join("cur.json");
    base.save(&base_path).unwrap();
    cur.save(&cur_path).unwrap();
    (dir, base_path, cur_path)
}

fn run_gate(base: &Path, cur: &Path, extra: &[&str]) -> (bool, String) {
    let out = bin()
        .args(["lab", "gate"])
        .arg(base)
        .arg(cur)
        .args(extra)
        .output()
        .expect("spawn lab gate");
    (out.status.success(), String::from_utf8_lossy(&out.stdout).into_owned())
}

#[test]
fn gate_fails_on_synthetic_fps_regression_and_margin_loosens_it() {
    let (dir, base, cur) = doctored("fps", |r| {
        // 10x slower than baseline in one cell
        r.cells[0].fps.median /= 10.0;
    });
    let (ok, stdout) = run_gate(&base, &cur, &[]);
    assert!(!ok, "10x fps drop must fail the default 2x margin:\n{stdout}");
    assert!(stdout.contains("FPS REGRESSED"), "{stdout}");
    // a margin wider than the regression passes
    let (ok_loose, _) = run_gate(&base, &cur, &["--margin", "20.0"]);
    assert!(ok_loose);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gate_fails_on_synthetic_quality_regression() {
    let (dir, base, cur) = doctored("mota", |r| {
        r.cells[1].quality.mota -= 0.5;
    });
    let (ok, stdout) = run_gate(&base, &cur, &[]);
    assert!(!ok, "0.5 MOTA drop must fail the default 0.1 margin:\n{stdout}");
    assert!(stdout.contains("MOTA REGRESSED"), "{stdout}");
    let (ok_loose, _) = run_gate(&base, &cur, &["--mota-margin", "0.9"]);
    assert!(ok_loose);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gate_ignores_mota_on_the_ingest_cell_but_still_gates_its_fps() {
    // the real-input cell's MOTA is a fixture property (pinned by the
    // ingest identity tests), so the baseline MOTA margin must not
    // apply to it — but throughput still gates
    let (dir, base, cur) = doctored("ingest_mota", |r| {
        let c = r.cells.iter_mut().find(|c| c.ingest.is_some()).expect("ingest cell");
        c.quality.mota -= 0.5;
    });
    let (ok, stdout) = run_gate(&base, &cur, &[]);
    assert!(ok, "ingest cells gate on FPS only:\n{stdout}");
    let (dir2, base2, cur2) = doctored("ingest_fps", |r| {
        let c = r.cells.iter_mut().find(|c| c.ingest.is_some()).expect("ingest cell");
        c.fps.median /= 10.0;
    });
    let (ok2, stdout2) = run_gate(&base2, &cur2, &[]);
    assert!(!ok2, "an ingest fps collapse must still fail:\n{stdout2}");
    assert!(stdout2.contains("FPS REGRESSED"), "{stdout2}");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir2);
}

#[test]
fn gate_fails_on_missing_cell_but_tolerates_new_cells() {
    let (dir, base, cur) = doctored("cover", |r| {
        let mut extra = r.cells[0].clone();
        extra.id = "native-d99-extra-s1".into();
        r.cells.push(extra);
        r.cells.remove(1);
    });
    let (ok, stdout) = run_gate(&base, &cur, &[]);
    assert!(!ok, "a dropped scenario is a coverage regression:\n{stdout}");
    assert!(stdout.contains("MISSING"), "{stdout}");
    assert!(stdout.contains("new"), "{stdout}");

    // additions alone pass
    let (dir2, base2, cur2) = doctored("cover2", |r| {
        let mut extra = r.cells[0].clone();
        extra.id = "native-d99-extra-s1".into();
        r.cells.push(extra);
    });
    let (ok2, stdout2) = run_gate(&base2, &cur2, &[]);
    assert!(ok2, "{stdout2}");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir2);
}

#[test]
fn gate_rejects_malformed_and_mismatched_schema_files() {
    let dir = tmpdir("bad");
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "{\"schema\": 99, \"kind\": \"lab\"}").unwrap();
    let out = bin()
        .args(["lab", "gate"])
        .arg(baseline_path())
        .arg(&bad)
        .output()
        .expect("spawn lab gate");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("schema"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
