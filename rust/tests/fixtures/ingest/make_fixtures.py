#!/usr/bin/env python3
"""Regenerate the checked-in ingest fixtures (deterministic).

Every number is quantized to 1/64 px (dyadic), so Python's repr and
Rust's shortest-roundtrip Display agree byte-for-byte, and the emitted
files are exact fixed points of the canonical writers in
rust/src/data/ingest/convert.rs:

  tiny.det.txt   -- MOT det.txt, 60 frames, 6 objects + dropout + FPs
  tiny.gt.txt    -- matching MOT gt.txt (ids 1..6, class/vis varied)
  tiny.coco.json -- byte-exact write_coco() of the parsed det fixture
  ambiguous.txt  -- id column mixes -1 and real ids (auto-detect must
                    return a typed "ambiguous" error)
  garbage.txt    -- not a detection format at all

Run from anywhere: python3 make_fixtures.py
CI regenerates nothing; the Rust round-trip tests and the convert CLI
re-serialize these files and `git diff --exit-code` pins the bytes.
"""

import os

HERE = os.path.dirname(os.path.abspath(__file__))
FRAMES = 60
Q = 64.0  # quantization grid (1/64 px)


class Lcg:
    """Same 64-bit LCG family the Rust fuzz harness uses."""

    def __init__(self, seed):
        self.state = seed & 0xFFFFFFFFFFFFFFFF

    def next_u64(self):
        self.state = (
            self.state * 6364136223846793005 + 1442695040888963407
        ) & 0xFFFFFFFFFFFFFFFF
        return self.state

    def unit(self):
        return (self.next_u64() >> 11) / float(1 << 53)


def q(x):
    """Snap to the 1/64 grid (keeps repr == Rust Display)."""
    return round(x * Q) / Q


def fmt(x):
    """Mirror convert.rs fmt_num: shortest roundtrip, ints without .0"""
    x = float(x)
    if x == int(x) and abs(x) < 1e15:
        return str(int(x))
    s = repr(x)
    assert "e" not in s and "E" not in s, f"exponent form would diverge: {s}"
    assert float(s) == x
    return s


# ---------------------------------------------------------------- gt --

# (id, first_frame, last_frame, l0, t0, w, h, vx, vy, class, visibility)
OBJECTS = [
    (1, 1, FRAMES, 40.0, 60.0, 36.0, 58.0, 3.5, 0.5, 1, 1.0),
    (2, 1, FRAMES, 520.0, 80.0, 34.0, 62.0, -3.0, 1.25, 1, 1.0),
    (3, 1, FRAMES, 120.0, 300.0, 40.0, 66.0, 2.25, -1.5, 1, 1.0),
    (4, 1, FRAMES, 420.0, 340.0, 30.0, 54.0, -1.75, -0.75, 1, 1.0),
    (5, 10, FRAMES, 260.0, 40.0, 38.0, 60.0, 0.5, 3.0, 2, 1.0),
    (6, 1, 45, 60.0, 400.0, 44.0, 70.0, 4.0, -2.0, 2, 0.75),
]


def gt_boxes():
    """{frame: [(id, l, t, w, h, class, vis)]}, frame-major, id order."""
    frames = {f: [] for f in range(1, FRAMES + 1)}
    for oid, f0, f1, l0, t0, w, h, vx, vy, cls, vis in OBJECTS:
        for f in range(f0, f1 + 1):
            k = f - f0
            l = q(l0 + vx * k)
            t = q(t0 + vy * k)
            frames[f].append((oid, max(0.0, l), max(0.0, t), w, h, cls, vis))
    return frames


def write_gt(frames):
    rows = []
    for f in range(1, FRAMES + 1):
        for oid, l, t, w, h, cls, vis in frames[f]:
            rows.append(
                f"{f},{oid},{fmt(l)},{fmt(t)},{fmt(w)},{fmt(h)},1,{cls},{fmt(vis)}\n"
            )
    return "".join(rows)


# --------------------------------------------------------------- det --

FP_FRAMES = {7, 19, 33, 51}  # frames that get one spurious detection


def det_rows(frames, rng):
    """[(frame, l, t, w, h, score)] frame-major, sorted like the writer."""
    rows = []
    for f in range(1, FRAMES + 1):
        for oid, l, t, w, h, _cls, _vis in frames[f]:
            # object 1 is never dropped so every frame has >=1 row and
            # the parsed sequence stays 60 frames long
            if oid != 1 and rng.unit() < 0.08:
                continue
            jl = max(0.0, q(l + (rng.unit() - 0.5) * 3.0))
            jt = max(0.0, q(t + (rng.unit() - 0.5) * 3.0))
            jw = max(1.0, q(w + (rng.unit() - 0.5) * 2.0))
            jh = max(1.0, q(h + (rng.unit() - 0.5) * 2.0))
            score = q(0.55 + 0.43 * rng.unit())
            rows.append((f, jl, jt, jw, jh, score))
        if f in FP_FRAMES:
            fl = q(600.0 * rng.unit())
            ft = q(420.0 * rng.unit())
            rows.append((f, fl, ft, q(20.0 + 10.0 * rng.unit()), q(40.0 + 10.0 * rng.unit()), 0.3))
    return rows


def write_det(rows):
    return "".join(
        f"{f},-1,{fmt(l)},{fmt(t)},{fmt(w)},{fmt(h)},{fmt(s)},-1,-1,-1\n"
        for f, l, t, w, h, s in rows
    )


# -------------------------------------------------------------- coco --


def jesc(s):
    out = ['"']
    for c in s:
        if c == '"':
            out.append('\\"')
        elif c == "\\":
            out.append("\\\\")
        elif c == "\n":
            out.append("\\n")
        elif c == "\r":
            out.append("\\r")
        elif c == "\t":
            out.append("\\t")
        elif ord(c) < 0x20:
            out.append("\\u%04x" % ord(c))
        else:
            out.append(c)
    out.append('"')
    return "".join(out)


def jpretty(v, depth=0):
    """Byte-mirror of data/json.rs write_value with indent=2."""
    pad, pad1 = "  " * depth, "  " * (depth + 1)
    if isinstance(v, (int, float)):
        return fmt(v)
    if isinstance(v, str):
        return jesc(v)
    if isinstance(v, list):
        if not v:
            return "[]"
        body = ",".join("\n" + pad1 + jpretty(e, depth + 1) for e in v)
        return "[" + body + "\n" + pad + "]"
    if isinstance(v, dict):
        if not v:
            return "{}"
        body = ",".join(
            "\n" + pad1 + jesc(k) + ": " + jpretty(v[k], depth + 1)
            for k in sorted(v)
        )
        return "{" + body + "\n" + pad + "}"
    raise TypeError(type(v))


def write_coco(rows):
    """Mirror convert.rs write_coco for a det-sourced IR (no image
    size, no classes, no track ids)."""
    images = [{"id": f} for f in range(1, FRAMES + 1)]
    annotations = []
    for i, (f, l, t, w, h, s) in enumerate(rows, start=1):
        annotations.append(
            {"id": i, "image_id": f, "bbox": [l, t, w, h], "score": s}
        )
    doc = {"annotations": annotations, "categories": [], "images": images}
    return jpretty(doc) + "\n"


# --------------------------------------------------------------- out --


def main():
    rng = Lcg(0x5EED0401)
    frames = gt_boxes()
    rows = det_rows(frames, rng)

    out = {
        "tiny.gt.txt": write_gt(frames),
        "tiny.det.txt": write_det(rows),
        "tiny.coco.json": write_coco(rows),
        "ambiguous.txt": (
            "1,-1,10,20,30,40,0.9,-1,-1,-1\n"
            "1,3,12,22,30,40,1,1,1\n"
            "2,-1,11,21,30,40,0.8,-1,-1,-1\n"
            "2,4,13,23,30,40,1,1,1\n"
        ),
        "garbage.txt": (
            "this file is not a detection file\n"
            "lorem ipsum dolor sit amet\n"
            "12 monkeys, no commas that parse\n"
        ),
    }
    for name, text in out.items():
        path = os.path.join(HERE, name)
        with open(path, "w", newline="") as fh:
            fh.write(text)
        print(f"wrote {name}: {len(text)} bytes, {text.count(chr(10))} lines")
    n_det = len(rows)
    n_gt = sum(len(v) for v in frames.values())
    print(f"det rows: {n_det}, gt rows: {n_gt}, frames: {FRAMES}, objects: {len(OBJECTS)}")


if __name__ == "__main__":
    main()
