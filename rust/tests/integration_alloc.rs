//! Allocation regression: the steady-state frame loop must be
//! allocation-free for the `native`, `batch` and `batchf32` engines.
//!
//! The paper's regime is "low actual work, high overhead" — a single
//! heap allocation costs more than the 7×7 arithmetic it would feed,
//! so `Sort::update`/`BatchSort::update` own every buffer they need
//! ([`smalltrack::sort::FrameScratch`]) and reuse them across frames.
//! This test pins that contract with a counting global allocator:
//! after a warm-up period (buffers growing to the stream's high-water
//! marks), **zero** allocations may happen per frame.
//!
//! The counter is itself thread-local, so the harness's own threads
//! (and the other tests in this binary, which libtest runs on
//! concurrent threads) can never pollute a measurement.

use smalltrack::engine::{EngineKind, TrackerEngine};
use smalltrack::sort::{Bbox, SortParams};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

struct CountingAllocator;

thread_local! {
    /// Per-thread allocation-event count (no cross-test interference).
    static ALLOC_EVENTS: Cell<u64> = const { Cell::new(0) };
}

/// Count one allocation event on the calling thread. `try_with` so
/// allocator re-entry during TLS teardown stays safe.
fn bump() {
    let _ = ALLOC_EVENTS.try_with(|c| c.set(c.get() + 1));
}

/// Read the calling thread's allocation-event count.
fn events() -> u64 {
    ALLOC_EVENTS.with(|c| c.get())
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAllocator = CountingAllocator;

/// Run `frames` frames produced by `make_frames` through `engine`,
/// counting this thread's allocation events after the first `warmup`.
fn count_steady_state_allocs(
    engine: &mut dyn TrackerEngine,
    make_frames: impl Fn(u64, &mut Vec<Bbox>),
    warmup: u64,
    frames: u64,
) -> u64 {
    let mut boxes: Vec<Bbox> = Vec::with_capacity(32);
    for k in 0..warmup {
        make_frames(k, &mut boxes);
        engine.update(&boxes);
    }
    let before = events();
    for k in warmup..frames {
        make_frames(k, &mut boxes);
        engine.update(&boxes);
    }
    events() - before
}

fn params() -> SortParams {
    SortParams { timing: false, ..Default::default() }
}

/// Eight well-separated objects on linear trajectories: unambiguous
/// association (the fast path fires), stable tracker population.
fn separated_objects(k: u64, boxes: &mut Vec<Bbox>) {
    boxes.clear();
    for i in 0..8u64 {
        let x = 100.0 + 400.0 * (i % 4) as f64 + 1.5 * k as f64;
        let y = 100.0 + 400.0 * (i / 4) as f64 + 0.5 * k as f64;
        boxes.push(Bbox::new(x, y, x + 40.0, y + 90.0));
    }
}

/// Two heavily-overlapping boxes moving together: every detection
/// overlaps both trackers above threshold, so the fast path never
/// fires and the Hungarian solver runs every single frame.
fn contested_objects(k: u64, boxes: &mut Vec<Bbox>) {
    boxes.clear();
    let x = 100.0 + 2.0 * k as f64;
    boxes.push(Bbox::new(x, 100.0, x + 60.0, 220.0));
    boxes.push(Bbox::new(x + 5.0, 104.0, x + 65.0, 224.0));
}

#[test]
fn native_engine_steady_state_is_allocation_free() {
    let mut engine = EngineKind::Native.build(params()).expect("build");
    let n = count_steady_state_allocs(&mut *engine, separated_objects, 60, 200);
    assert_eq!(n, 0, "native engine allocated {n} times in 140 steady-state frames");
}

#[test]
fn batch_engine_steady_state_is_allocation_free() {
    let mut engine = EngineKind::Batch.build(params()).expect("build");
    let n = count_steady_state_allocs(&mut *engine, separated_objects, 60, 200);
    assert_eq!(n, 0, "batch engine allocated {n} times in 140 steady-state frames");
}

#[test]
fn batchf32_engine_steady_state_is_allocation_free() {
    // the f32 tier's lane blocks and gather/scatter buffers are all
    // fixed-size stack arrays — same zero-alloc contract as f64
    let mut engine = EngineKind::BatchF32.build(params()).expect("build");
    let n = count_steady_state_allocs(&mut *engine, separated_objects, 60, 200);
    assert_eq!(n, 0, "batchf32 engine allocated {n} times in 140 steady-state frames");
}

#[test]
fn hungarian_slow_path_is_allocation_free() {
    // the contested scenario defeats the partial-permutation fast path,
    // so this pins the Hungarian solver + its transpose-free scratch
    for kind in [EngineKind::Native, EngineKind::Batch, EngineKind::BatchF32] {
        let mut engine = kind.build(params()).expect("build");
        let n = count_steady_state_allocs(&mut *engine, contested_objects, 60, 200);
        assert_eq!(
            n,
            0,
            "{} engine allocated {n} times on the Hungarian path",
            kind.label()
        );
    }
}

#[test]
fn hungarian_transpose_branch_is_allocation_free() {
    // rows > cols takes the transpose path, whose workspace moved from
    // a fresh `vec![0.0; rows*cols]` into the scratch — the engine
    // loops rarely hit this shape in steady state, so pin it directly
    use smalltrack::sort::hungarian::{hungarian_min_cost_into, HungarianScratch};
    let cost = [0.9, 0.1, 0.4, 0.6, 0.2, 0.8, 0.7, 0.3]; // 4x2
    let mut scratch = HungarianScratch::default();
    let mut out = Vec::new();
    hungarian_min_cost_into(&cost, 4, 2, &mut scratch, &mut out); // warm-up
    let before = events();
    for _ in 0..100 {
        hungarian_min_cost_into(&cost, 4, 2, &mut scratch, &mut out);
    }
    let n = events() - before;
    assert_eq!(n, 0, "transpose-branch solve allocated {n} times after warm-up");
    assert_eq!(out.len(), 4);
    assert_eq!(out.iter().flatten().count(), 2, "both columns assigned");
}

#[test]
fn warmup_does_allocate() {
    // sanity check on the harness itself: the counter must actually
    // see the warm-up growth, otherwise the zero above proves nothing
    let mut engine = EngineKind::Native.build(params()).expect("build");
    let mut boxes: Vec<Bbox> = Vec::with_capacity(32);
    let before = events();
    for k in 0..10 {
        separated_objects(k, &mut boxes);
        engine.update(&boxes);
    }
    assert!(events() > before, "counting allocator saw no warm-up allocations");
}
