//! Coordinator-level properties: routing invariants, policy agreement,
//! serving vs offline equivalence on randomized workloads.

use smalltrack::coordinator::backpressure::PushPolicy;
use smalltrack::coordinator::policy::{outcomes_consistent, run_policy, run_sequence_serial};
use smalltrack::coordinator::{
    serve, Pacing, RoutePolicy, Router, ScalingPolicy, ServerConfig, VideoStream,
};
use smalltrack::data::synth::{generate_sequence, SynthConfig, SynthSequence};
use smalltrack::proptest_lite::{ensure, run_named, Config};
use smalltrack::sort::SortParams;

fn random_suite(rng: &mut smalltrack::prng::Rng, max_seqs: u64) -> Vec<SynthSequence> {
    let n = 1 + rng.below(max_seqs) as usize;
    (0..n)
        .map(|i| {
            let frames = 20 + rng.below(80) as u32;
            let objs = 2 + rng.below(8) as u32;
            generate_sequence(&SynthConfig::mot15(&format!("R{i}"), frames, objs, rng.next_u64()))
        })
        .collect()
}

#[test]
fn prop_router_pins_and_balances() {
    run_named(
        "router-invariants",
        Config { cases: 100, seed: 0x40073 },
        |rng| {
            let workers = 1 + rng.below(8) as usize;
            let streams: Vec<usize> = (0..rng.below(40)).map(|_| rng.below(1000) as usize).collect();
            (workers, streams)
        },
        |(workers, streams)| {
            let mut r = Router::new(*workers, RoutePolicy::LeastLoaded);
            let mut first: std::collections::HashMap<usize, usize> = Default::default();
            for &s in streams {
                let w = r.route(s);
                ensure(w < *workers, "worker in range")?;
                if let Some(&w0) = first.get(&s) {
                    ensure(w0 == w, format!("stream {s} re-routed {w0} -> {w}"))?;
                } else {
                    first.insert(s, w);
                }
            }
            // balance: max-min load <= 1 for unique streams
            let unique = first.len();
            let loads = r.loads();
            let max = loads.iter().max().unwrap();
            let min = loads.iter().min().unwrap();
            ensure(
                max - min <= 1 && loads.iter().sum::<usize>() == unique,
                format!("unbalanced {loads:?}"),
            )
        },
    );
}

#[test]
fn prop_scaling_policies_agree_on_output() {
    run_named(
        "policies-agree",
        Config { cases: 12, seed: 0xACE },
        |rng| random_suite(rng, 5),
        |suite| {
            let params = SortParams { timing: false, ..Default::default() };
            let outcomes: Vec<_> = [
                ScalingPolicy::Strong { threads: 2 },
                ScalingPolicy::Weak { workers: 3 },
                ScalingPolicy::Throughput { workers: 2 },
            ]
            .into_iter()
            .map(|p| run_policy(suite, p, params))
            .collect();
            ensure(outcomes_consistent(&outcomes), format!("{outcomes:?}"))
        },
    );
}

#[test]
fn prop_lossless_serving_equals_offline() {
    run_named(
        "serve-equals-offline",
        Config { cases: 8, seed: 0x5E4E },
        |rng| random_suite(rng, 4),
        |suite| {
            let params = SortParams { timing: false, ..Default::default() };
            let offline: u64 = suite.iter().map(|s| run_sequence_serial(s, params).1).sum();
            let streams: Vec<VideoStream> = suite
                .iter()
                .enumerate()
                .map(|(i, s)| VideoStream::new(i, s.sequence.clone(), Pacing::Unpaced))
                .collect();
            let report = serve(
                streams,
                ServerConfig {
                    workers: 2,
                    push_policy: PushPolicy::Block,
                    sort_params: params,
                    ..Default::default()
                },
            );
            ensure(report.dropped == 0, "no drops under Block")?;
            ensure(
                report.tracks_out == offline,
                format!("served {} vs offline {offline}", report.tracks_out),
            )
        },
    );
}

#[test]
fn full_table1_suite_runs_and_reports() {
    let suite = smalltrack::data::synth::generate_suite(7);
    let params = SortParams { timing: false, ..Default::default() };
    let outcome = run_policy(&suite, ScalingPolicy::Weak { workers: 2 }, params);
    assert_eq!(outcome.frames, 5500);
    assert_eq!(outcome.files, 11);
    assert!(outcome.fps() > 1000.0, "suspiciously slow: {}", outcome.fps());
    assert!(outcome.tracks_out > 10_000, "tracks_out {}", outcome.tracks_out);
}
