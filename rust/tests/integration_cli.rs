//! CLI smoke tests: the deployable binary end to end (gen-data →
//! track → scaling → simulate), via `CARGO_BIN_EXE_smalltrack`.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_smalltrack"))
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("smalltrack_cli_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_lists_commands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["gen-data", "track", "suite", "serve", "scaling", "simulate", "xla"] {
        assert!(text.contains(cmd), "missing {cmd}");
    }
}

#[test]
fn unknown_command_fails() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn gen_data_then_track_roundtrip() {
    let dir = tmpdir("roundtrip");
    let out = bin().args(["gen-data", "--out"]).arg(&dir).args(["--seed", "3"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let det = dir.join("TUD-Campus/det/det.txt");
    assert!(det.exists());

    let tracks_dir = dir.join("tracks");
    let out = bin()
        .args(["track", "--det"])
        .arg(&det)
        .arg("--out")
        .arg(&tracks_dir)
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"frames\": 71"), "{stdout}");
    // track output exists and is MOT-formatted
    let track_file = tracks_dir.join("TUD-Campus.txt");
    let body = std::fs::read_to_string(&track_file).unwrap();
    let first = body.lines().next().unwrap();
    assert!(first.split(',').count() >= 10, "{first}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn suite_reports_5500_frames() {
    let out = bin().arg("suite").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("5500 frames"), "{text}");
    assert!(text.contains("Venice-2"));
}

#[test]
fn scaling_policies_run() {
    for policy in ["strong", "weak", "throughput"] {
        let out = bin().args(["scaling", "--policy", policy, "--p", "2"]).output().unwrap();
        assert!(out.status.success(), "{policy}");
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("frames=5500"), "{policy}: {text}");
    }
}

#[test]
fn scaling_sharded_reports_workers() {
    for shard in ["pinned", "stealing"] {
        let out = bin()
            .args(["scaling", "--policy", "sharded", "--workers", "2", "--shard-policy", shard])
            .output()
            .unwrap();
        assert!(out.status.success(), "{shard}: {}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains(&format!("sharded(p=2,{shard})")), "{text}");
        assert!(text.contains("frames=5500"), "{shard}: {text}");
        assert!(text.contains("worker 0:"), "{text}");
        assert!(text.contains("worker 1:"), "{text}");
        if shard == "pinned" {
            assert!(text.contains("stolen=0"), "pinned must not steal: {text}");
        }
    }
}

#[test]
fn serve_sharded_mode_runs() {
    let out = bin()
        .args(["serve", "--workers", "2", "--shard-policy", "stealing"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("sharded (stealing)"), "{text}");
    assert!(text.contains("frames=5500"), "{text}");
}

#[test]
fn scaling_with_real_processes() {
    let out = bin().args(["scaling", "--processes", "--p", "2"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("throughput-processes(p=2)"), "{text}");
    assert!(text.contains("frames=5500"), "{text}");
}

#[test]
fn simulate_prints_table6() {
    let out = bin().args(["simulate", "--machine", "skx6140"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Table VI"));
    assert!(text.contains("72"));
}
