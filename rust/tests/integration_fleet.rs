//! Fleet integration: the shard-per-core router end to end.
//!
//! The contract under test (the fleet tentpole's acceptance criteria):
//! a `TrackRouter` fronting N shard servers must preserve the single
//! server's recovery guarantees — delivered tracks **bit-identical**
//! (`f64::to_bits`) to an in-process run and a conserved frame ledger
//! (`frames_sent == frames_acked + rejected + in_flight_at_close`) —
//! while adding session affinity (a session and every RESUME for it
//! land on the FNV-owned shard) and shard-restart recovery (a shard
//! killed mid-stream is replaced and its sessions re-driven from the
//! router's bank). Covered at three levels: the in-process netload
//! fleet harness, the seeded fault schedule plus scheduled shard
//! kills, and the `netload` / `track-router` CLI binaries over real
//! loopback TCP with real shard child processes.

use smalltrack::coordinator::faults::FaultPlan;
use smalltrack::coordinator::fleet::shard_of;
use smalltrack::coordinator::net::{
    approx_upstream_bytes, detection_frames, netload_run, NetloadOptions,
};
use smalltrack::data::synth::{generate_sequence, SynthConfig};
use smalltrack::engine::EngineKind;
use smalltrack::sort::Bbox;
use std::io::BufRead;
use std::process::{Child, Command, Stdio};

fn synth_stream(name: &str, frames: u32, objects: u32, seed: u64) -> Vec<Vec<Bbox>> {
    let cfg = SynthConfig::mot15(name, frames, objects, seed);
    detection_frames(&generate_sequence(&cfg).sequence)
}

fn fleet_opts(shards: usize, seed: u64) -> NetloadOptions {
    let mut opts = NetloadOptions::new(EngineKind::Batch);
    opts.seed = seed;
    opts.checkpoint_every = 8;
    opts.router_shards = shards;
    opts
}

/// The occupancy the router must report if every session landed on its
/// FNV-owned shard: netload keys stream `i` as `0xC0FF_EE00 + i`.
fn expected_occupancy(streams: usize, shards: usize) -> Vec<u64> {
    let mut expect = vec![0u64; shards];
    for i in 0..streams as u64 {
        expect[shard_of(0xC0FF_EE00 + i, shards)] += 1;
    }
    expect
}

// --- in-process level ----------------------------------------------------

#[test]
fn two_shard_fleet_matches_the_serial_reference_bit_for_bit() {
    let streams: Vec<_> = (0..4)
        .map(|i| synth_stream(&format!("fleet-clean{i}"), 50, 4, 21 + i as u64))
        .collect();
    let out = netload_run(fleet_opts(2, 21), &streams).expect("fleet netload");

    assert!(out.bit_identical, "fleet tracks diverged from the in-process reference run");
    let l = &out.ledger;
    assert!(l.conserves(), "{l:?}");
    assert_eq!(l.frames_sent, 200, "{l:?}");
    assert_eq!(l.frames_acked, 200, "{l:?}");
    assert_eq!(out.shard_kills, 0, "no kills were scheduled");

    // in fleet mode the reported counters are the router's: the
    // client-facing view, including per-shard occupancy
    let sc = out.server_counters.as_ref().unwrap();
    assert_eq!(sc.sessions_opened, 4, "{sc:?}");
    assert_eq!(sc.per_shard_sessions.len(), 2, "{sc:?}");
    assert_eq!(
        sc.per_shard_sessions,
        expected_occupancy(4, 2),
        "every session must land on its FNV-owned shard"
    );
    // the hash spreads the netload keyspace over both shards, so this
    // cell genuinely exercises multi-shard routing
    assert!(sc.per_shard_sessions.iter().all(|&n| n > 0), "{sc:?}");
}

#[test]
fn session_affinity_holds_across_cuts_and_resumes() {
    let streams: Vec<_> = (0..3)
        .map(|i| synth_stream(&format!("fleet-cuts{i}"), 60, 4, 13 + i as u64))
        .collect();
    let mut opts = fleet_opts(2, 13);
    let span: u64 = streams.iter().map(|s| approx_upstream_bytes(s)).sum();
    opts.faults = Some(FaultPlan::aggressive(13, span, 3));
    let out = netload_run(opts, &streams).expect("faulted fleet netload");

    assert!(out.bit_identical, "recovery must reconverge on the reference tracks");
    assert!(out.ledger.conserves(), "{:?}", out.ledger);
    assert!(out.ledger.reconnects >= 1, "aggressive cuts must force resumes: {:?}", out.ledger);
    let sc = out.server_counters.as_ref().unwrap();
    // occupancy counts *fresh* sessions only — if a RESUME ever landed
    // on (and re-opened at) the wrong shard, a shard would show a twin
    assert_eq!(
        sc.per_shard_sessions,
        expected_occupancy(3, 2),
        "a resumed session must come back to the shard that owns its key: {sc:?}"
    );
}

#[test]
fn a_mid_stream_shard_kill_recovers_with_a_conserved_ledger() {
    let streams: Vec<_> = (0..2)
        .map(|i| synth_stream(&format!("fleet-kill{i}"), 80, 5, 5 + i as u64))
        .collect();
    let mut opts = fleet_opts(2, 5);
    let span: u64 = streams.iter().map(|s| approx_upstream_bytes(s)).sum();
    // no byte faults at all — the only disruption is a shard dying
    // mid-stream and being replaced by an empty one, so any ledger or
    // bit-identity failure is squarely the router's re-drive
    opts.faults = Some(FaultPlan::none().with_shard_kills(1, 5, span));
    let out = netload_run(opts, &streams).expect("shard-kill fleet netload");

    assert_eq!(out.shard_kills, 1, "the scheduled kill must actually fire");
    assert!(out.bit_identical, "re-driven sessions must reproduce the reference tracks");
    let l = &out.ledger;
    assert!(l.conserves(), "{l:?}");
    assert_eq!(l.frames_sent, 160, "{l:?}");
    assert_eq!(l.frames_acked, 160, "a kill costs retries, never frames: {l:?}");
}

// --- CLI level -----------------------------------------------------------

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_smalltrack"))
}

#[test]
fn netload_cli_router_mode_enforces_the_contract_and_reports_the_fleet() {
    let dir = std::env::temp_dir().join(format!("smalltrack_fleet_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let json = dir.join("fleet.json");
    let out = bin()
        .args(["netload", "--streams", "2", "--frames", "40", "--engine", "batch"])
        .args(["--router", "2", "--kills", "1", "--faults", "aggressive", "--cuts", "2"])
        .args(["--seed", "7", "--json"])
        .arg(&json)
        .output()
        .expect("spawn netload");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "netload --router failed:\n{stdout}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("OK: ledger conserves"), "{stdout}");
    assert!(stdout.contains("shard_kills="), "fleet line missing: {stdout}");

    let report = smalltrack::data::json::parse(&std::fs::read_to_string(&json).unwrap())
        .expect("fleet report is valid JSON");
    assert_eq!(report.req("router_shards").num(), 2.0);
    assert_eq!(report.req("shard_kills").num(), 1.0);
    assert_eq!(report.req("bit_identical").as_bool(), Some(true));
    assert_eq!(report.req("conserves").as_bool(), Some(true));
    assert_eq!(report.req("frames_sent").num(), 80.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn netload_cli_rejects_kills_without_a_router() {
    let out = bin()
        .args(["netload", "--streams", "1", "--frames", "5", "--kills", "1"])
        .output()
        .expect("spawn netload");
    assert!(!out.status.success(), "--kills without --router must be an error");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--kills requires --router"), "{stderr}");
}

/// Kills the router child even when an assert unwinds. Its shard
/// children exit on their own: each holds a stdin pipe from the router
/// and exits on EOF (the parent-death watchdog), so a killed router
/// never leaks shard processes.
struct KillOnDrop(Child);
impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn netload_cli_reaches_a_track_router_fleet_over_loopback() {
    // real three-process deployment: `track-router` supervising two
    // `track-serve` shard children, `netload --addr` pointed at it
    let child = bin()
        .args(["track-router", "--addr", "127.0.0.1:0", "--shards", "2", "--workers", "2"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn track-router");
    let mut guard = KillOnDrop(child);
    let stdout = guard.0.stdout.take().expect("piped stdout");
    let mut lines = std::io::BufReader::new(stdout).lines();
    let banner = lines
        .next()
        .expect("track-router printed nothing")
        .expect("read track-router banner");
    // "track-router listening on 127.0.0.1:PORT (2 shards x 2 workers, ...)"
    let addr = banner
        .split_whitespace()
        .find(|w| w.starts_with("127.0.0.1:"))
        .unwrap_or_else(|| panic!("no address in banner: {banner}"))
        .to_string();
    assert_ne!(addr, "127.0.0.1:0", "router must report the real port");

    let out = bin()
        .args(["netload", "--streams", "2", "--frames", "40", "--engine", "batch", "--addr"])
        .arg(&addr)
        .output()
        .expect("spawn netload");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "netload vs track-router failed:\n{stdout}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("OK: ledger conserves"), "{stdout}");
}
