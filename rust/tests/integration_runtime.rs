//! L3↔L2/L1 integration: the kernel runtime executing the bank
//! artifacts.
//!
//! Verifies that (a) the bank kernels agree numerically with the native
//! Rust filter and (b) the full bank tracker produces the same tracks
//! as the native `Sort` on a real synthetic sequence — i.e. the
//! three-layer stack composes.
//!
//! Runs unconditionally: without `make artifacts` the runtime executes
//! the built-in reference interpreter over the default bank geometry,
//! so a fresh clone still exercises the whole bank path; with the
//! artifacts present the same assertions pin the manifest geometry
//! (and the compiled kernels, once the `pjrt` backend is enabled).

use smalltrack::data::synth::{generate_sequence, SynthConfig};
use smalltrack::runtime::{TrackerBank, XlaRuntime};
use smalltrack::sort::kalman::{CovarianceForm, KalmanState, SortConstants};
use smalltrack::sort::{Bbox, Sort, SortParams};

fn runtime() -> XlaRuntime {
    XlaRuntime::new().expect("kernel runtime")
}

#[test]
fn predict_artifact_matches_native_kalman() {
    let rt = runtime();
    let art = rt.load("bank_predict_T16").unwrap();

    // 16 slots: 5 live with distinct states, rest dead
    let consts = SortConstants::sort_defaults();
    let mut x = vec![0.0; 16 * 7];
    let mut p = vec![0.0; 16 * 7 * 7];
    let mut mask = vec![0.0; 16];
    let mut native: Vec<KalmanState> = Vec::new();
    for i in 0..5 {
        let z = [100.0 * (i + 1) as f64, 50.0 * (i + 1) as f64, 2000.0 + 100.0 * i as f64, 0.5];
        let mut s = KalmanState::from_measurement(&z, &consts);
        s.x[4] = i as f64 - 2.0;
        s.x[5] = 0.5 * i as f64;
        for k in 0..7 {
            x[i * 7 + k] = s.x[k];
            for c in 0..7 {
                p[i * 49 + k * 7 + c] = s.p[(k, c)];
            }
        }
        mask[i] = 1.0;
        native.push(s);
    }

    let outs = art.run(&[&x, &p, &mask]).unwrap();
    let (xn, pn) = (&outs[0], &outs[1]);

    for (i, s) in native.iter_mut().enumerate() {
        s.predict(&consts);
        for k in 0..7 {
            assert!(
                (xn[i * 7 + k] - s.x[k]).abs() < 1e-9,
                "slot {i} x[{k}]: {} vs {}",
                xn[i * 7 + k],
                s.x[k]
            );
            for c in 0..7 {
                assert!(
                    (pn[i * 49 + k * 7 + c] - s.p[(k, c)]).abs() < 1e-9,
                    "slot {i} P[{k}][{c}]"
                );
            }
        }
    }
    // dead slots untouched
    for i in 5..16 {
        for k in 0..7 {
            assert_eq!(xn[i * 7 + k], 0.0);
        }
    }
}

#[test]
fn update_artifact_matches_native_kalman() {
    let rt = runtime();
    let art = rt.load("bank_update").unwrap();
    let consts = SortConstants::sort_defaults();

    let mut x = vec![0.0; 16 * 7];
    let mut p = vec![0.0; 16 * 7 * 7];
    let mut z = vec![0.0; 16 * 4];
    let mut zmask = vec![0.0; 16];
    let mut native: Vec<KalmanState> = Vec::new();
    for i in 0..4 {
        let seed = [200.0 + 30.0 * i as f64, 100.0, 3000.0, 0.6];
        let mut s = KalmanState::from_measurement(&seed, &consts);
        s.predict(&consts);
        for k in 0..7 {
            x[i * 7 + k] = s.x[k];
            for c in 0..7 {
                p[i * 49 + k * 7 + c] = s.p[(k, c)];
            }
        }
        let meas = [seed[0] + 2.0, seed[1] - 1.0, seed[2] + 50.0, 0.6];
        z[i * 4..(i + 1) * 4].copy_from_slice(&meas);
        zmask[i] = 1.0;
        native.push(s);
    }

    let outs = art.run(&[&x, &p, &z, &zmask]).unwrap();
    for (i, s) in native.iter_mut().enumerate() {
        let zi = [z[i * 4], z[i * 4 + 1], z[i * 4 + 2], z[i * 4 + 3]];
        assert!(s.update(&zi, &consts, CovarianceForm::Joseph));
        for k in 0..7 {
            assert!(
                (outs[0][i * 7 + k] - s.x[k]).abs() < 1e-8,
                "slot {i} x[{k}]: {} vs {}",
                outs[0][i * 7 + k],
                s.x[k]
            );
        }
        // covariance within fp tolerance of the Joseph form
        for k in 0..49 {
            let (r, c) = (k / 7, k % 7);
            assert!(
                (outs[1][i * 49 + k] - s.p[(r, c)]).abs() < 1e-7,
                "slot {i} P[{r}][{c}]"
            );
        }
    }
}

#[test]
fn xla_bank_tracker_matches_native_sort_end_to_end() {
    let rt = runtime();
    let params = SortParams { timing: false, ..Default::default() };
    let mut bank = TrackerBank::new(&rt, params).unwrap();
    let mut native = Sort::new(params);

    // synthetic sequence bounded to the bank capacity
    let synth = generate_sequence(&SynthConfig::mot15("XLAE2E", 120, 8, 23));
    for frame in &synth.sequence.frames {
        let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
        let mut a: Vec<_> = native.update(&boxes).to_vec();
        let mut b: Vec<_> = bank.update(&boxes).unwrap().to_vec();
        a.sort_by_key(|t| t.id);
        b.sort_by_key(|t| t.id);
        assert_eq!(
            a.iter().map(|t| t.id).collect::<Vec<_>>(),
            b.iter().map(|t| t.id).collect::<Vec<_>>(),
            "frame {}: ids diverge",
            frame.index
        );
        for (x, y) in a.iter().zip(&b) {
            assert!((x.bbox.x1 - y.bbox.x1).abs() < 1e-6, "frame {}", frame.index);
            assert!((x.bbox.y1 - y.bbox.y1).abs() < 1e-6, "frame {}", frame.index);
            assert!((x.bbox.x2 - y.bbox.x2).abs() < 1e-6, "frame {}", frame.index);
            assert!((x.bbox.y2 - y.bbox.y2).abs() < 1e-6, "frame {}", frame.index);
        }
    }
    assert_eq!(bank.overflow_dets, 0);
}

#[test]
fn predict_sweep_artifacts_all_load_and_run() {
    let rt = runtime();
    for t in [1usize, 4, 16, 64, 256] {
        let art = rt.load(&format!("bank_predict_T{t}")).unwrap();
        let x = vec![1.0; t * 7];
        let p = vec![0.5; t * 49];
        let mask = vec![1.0; t];
        let outs = art.run(&[&x, &p, &mask]).unwrap();
        assert_eq!(outs[0].len(), t * 7);
        assert_eq!(outs[1].len(), t * 49);
        assert!(outs[0].iter().all(|v| v.is_finite()));
    }
}
