//! Property tests: the Hungarian solver vs an exhaustive oracle, and
//! structural invariants at arbitrary shapes.

use smalltrack::proptest_lite::{ensure, run_named, Config};
use smalltrack::sort::hungarian::{
    assignment_cost, brute_force_min_cost, hungarian_min_cost, HungarianScratch,
};

#[test]
fn prop_optimal_vs_brute_force_small_shapes() {
    run_named(
        "hungarian-optimal",
        Config { cases: 400, seed: 0xB10C },
        |rng| {
            let rows = 1 + rng.below(5) as usize;
            let cols = 1 + rng.below(5) as usize;
            let cost: Vec<f64> = (0..rows * cols).map(|_| rng.range(-10.0, 10.0)).collect();
            (rows, cols, cost)
        },
        |(rows, cols, cost)| {
            let mut s = HungarianScratch::default();
            let asn = hungarian_min_cost(cost, *rows, *cols, &mut s);
            let got = assignment_cost(cost, *cols, &asn);
            let (want, _) = brute_force_min_cost(cost, *rows, *cols);
            ensure(
                (got - want).abs() < 1e-9,
                format!("suboptimal: got {got}, optimal {want}"),
            )
        },
    );
}

#[test]
fn prop_assignment_is_partial_permutation() {
    run_named(
        "hungarian-permutation",
        Config { cases: 400, seed: 0xFACE },
        |rng| {
            let rows = 1 + rng.below(13) as usize; // Table I max objects
            let cols = 1 + rng.below(13) as usize;
            let cost: Vec<f64> = (0..rows * cols).map(|_| -rng.uniform()).collect(); // -IoU range
            (rows, cols, cost)
        },
        |(rows, cols, cost)| {
            let mut s = HungarianScratch::default();
            let asn = hungarian_min_cost(cost, *rows, *cols, &mut s);
            ensure(asn.len() == *rows, "one entry per row")?;
            let assigned: Vec<usize> = asn.iter().flatten().copied().collect();
            // exactly min(rows, cols) assignments
            ensure(
                assigned.len() == *rows.min(cols),
                format!("{} assigned, want {}", assigned.len(), rows.min(cols)),
            )?;
            // columns unique and in range
            let mut cols_seen = assigned.clone();
            cols_seen.sort_unstable();
            let before = cols_seen.len();
            cols_seen.dedup();
            ensure(cols_seen.len() == before, "duplicate column")?;
            ensure(cols_seen.iter().all(|c| c < cols), "column out of range")
        },
    );
}

#[test]
fn prop_invariant_under_row_constant_shift() {
    // adding a constant to a row must not change the argmin assignment
    run_named(
        "hungarian-shift-invariance",
        Config { cases: 200, seed: 0x5111F7 },
        |rng| {
            let n = 2 + rng.below(5) as usize;
            let cost: Vec<f64> = (0..n * n).map(|_| rng.range(0.0, 100.0)).collect();
            let row = rng.below(n as u64) as usize;
            let shift = rng.range(-50.0, 50.0);
            (n, cost, row, shift)
        },
        |(n, cost, row, shift)| {
            let mut s = HungarianScratch::default();
            let base = hungarian_min_cost(cost, *n, *n, &mut s);
            let mut shifted = cost.clone();
            for c in 0..*n {
                shifted[row * n + c] += shift;
            }
            let after = hungarian_min_cost(&shifted, *n, *n, &mut s);
            let cost_base = assignment_cost(cost, *n, &base);
            let cost_after = assignment_cost(cost, *n, &after);
            // assignments may differ under ties, but value must match
            ensure(
                (cost_base - cost_after).abs() < 1e-9,
                format!("{cost_base} vs {cost_after}"),
            )
        },
    );
}

#[test]
fn prop_transpose_duality() {
    // optimal value of cost == optimal value of its transpose
    run_named(
        "hungarian-transpose",
        Config { cases: 200, seed: 0x7A27 },
        |rng| {
            let rows = 1 + rng.below(6) as usize;
            let cols = 1 + rng.below(6) as usize;
            let cost: Vec<f64> = (0..rows * cols).map(|_| rng.range(0.0, 10.0)).collect();
            (rows, cols, cost)
        },
        |(rows, cols, cost)| {
            let mut s = HungarianScratch::default();
            let a = hungarian_min_cost(cost, *rows, *cols, &mut s);
            let va = assignment_cost(cost, *cols, &a);
            let mut t = vec![0.0; rows * cols];
            for r in 0..*rows {
                for c in 0..*cols {
                    t[c * rows + r] = cost[r * cols + c];
                }
            }
            let b = hungarian_min_cost(&t, *cols, *rows, &mut s);
            let vb = assignment_cost(&t, *rows, &b);
            ensure((va - vb).abs() < 1e-9, format!("{va} vs {vb}"))
        },
    );
}
