//! Scheduler equivalence: the work-stealing shard scheduler must be a
//! pure throughput transform — pinned and stealing shard policies, at
//! any worker count, produce byte-identical tracks to a fresh
//! single-threaded `Sort` run on the same synthetic streams.
//!
//! This is the determinism contract that makes the scheduler safe to
//! deploy: which worker runs a stream, and in what order streams
//! complete, must never leak into the tracking output.

use smalltrack::coordinator::scheduler::{run_shards, SchedulerConfig, ShardPolicy};
use smalltrack::data::synth::{generate_sequence, SynthConfig, SynthSequence};
use smalltrack::engine::EngineKind;
use smalltrack::sort::{Bbox, SortParams};

fn params() -> SortParams {
    SortParams { timing: false, ..Default::default() }
}

/// A heterogeneous suite: frame counts 40..260, object counts 3..7 —
/// enough spread that stealing actually happens at 2 and 8 workers.
fn suite() -> Vec<SynthSequence> {
    (0..10)
        .map(|i| {
            let frames = 40 + 55 * (i as u32 % 5);
            let objects = 3 + (i as u32 % 5);
            generate_sequence(&SynthConfig::mot15(&format!("SCHED-{i}"), frames, objects, i as u64))
        })
        .collect()
}

/// Reference: single-threaded native `Sort`, one fresh engine per
/// stream, collecting `(frame, id, bbox)` rows.
fn serial_rows(suite: &[SynthSequence]) -> Vec<Vec<(u32, u64, Bbox)>> {
    suite
        .iter()
        .map(|s| {
            let mut engine = EngineKind::Native.build(params()).expect("build");
            let mut rows = Vec::new();
            let mut boxes: Vec<Bbox> = Vec::new();
            for frame in &s.sequence.frames {
                boxes.clear();
                boxes.extend(frame.detections.iter().map(|d| d.bbox));
                for t in engine.update(&boxes) {
                    rows.push((frame.index, t.id, t.bbox));
                }
            }
            rows
        })
        .collect()
}

/// Render rows as MOT track-file lines so "byte-identical" is checked
/// on actual serialized bytes, not just on f64 equality.
fn to_bytes(rows: &[(u32, u64, Bbox)]) -> Vec<u8> {
    let mut out = String::new();
    for (frame, id, b) in rows {
        out.push_str(&format!(
            "{},{},{:.2},{:.2},{:.2},{:.2},1,-1,-1,-1\n",
            frame,
            id,
            b.x1,
            b.y1,
            b.x2 - b.x1,
            b.y2 - b.y1
        ));
    }
    out.into_bytes()
}

#[test]
fn shard_policies_are_byte_identical_to_serial_sort() {
    let suite = suite();
    let reference = serial_rows(&suite);
    for workers in [1usize, 2, 8] {
        for policy in [ShardPolicy::Pinned, ShardPolicy::Stealing] {
            let report = run_shards(
                &suite,
                SchedulerConfig {
                    workers,
                    shard_policy: policy,
                    sort_params: params(),
                    collect_tracks: true,
                    ..Default::default()
                },
            );
            assert_eq!(report.shed, 0);
            assert_eq!(report.outputs.len(), suite.len(), "w={workers} {}", policy.label());
            for (out, want) in report.outputs.iter().zip(&reference) {
                assert_eq!(
                    out.rows, *want,
                    "stream {} (w={workers}, {}) diverged from serial Sort",
                    out.stream_id,
                    policy.label()
                );
                assert_eq!(
                    to_bytes(&out.rows),
                    to_bytes(want),
                    "stream {} (w={workers}, {}) serialized bytes differ",
                    out.stream_id,
                    policy.label()
                );
            }
        }
    }
}

#[test]
fn stealing_actually_steals_on_imbalanced_shards() {
    // streams 0 and 2 (both homed on worker 0) carry ~27x the frames
    // of the short clips: worker 0 cannot clear its deque before
    // worker 1 drains its own short shard and comes stealing,
    // regardless of pop order or thread start timing
    let mut suite: Vec<SynthSequence> = Vec::new();
    for i in 0..8u32 {
        let frames = if i == 0 || i == 2 { 800 } else { 30 };
        suite.push(generate_sequence(&SynthConfig::mot15(
            &format!("S{i}"),
            frames,
            5,
            i as u64,
        )));
    }
    let report = run_shards(
        &suite,
        SchedulerConfig {
            workers: 2,
            shard_policy: ShardPolicy::Stealing,
            sort_params: params(),
            ..Default::default()
        },
    );
    assert_eq!(report.streams, 8);
    // worker 0's home shard carries 1660 frames vs worker 1's 120:
    // whichever thread runs ahead must cross shards to finish the batch
    assert!(report.stolen > 0, "no steals despite a 14x-imbalanced shard");
    // pinned on the same suite must not steal
    let pinned = run_shards(
        &suite,
        SchedulerConfig {
            workers: 2,
            shard_policy: ShardPolicy::Pinned,
            sort_params: params(),
            ..Default::default()
        },
    );
    assert_eq!(pinned.stolen, 0);
    assert_eq!(pinned.tracks_out, report.tracks_out, "steal policy changed tracker output");
}

#[test]
fn repeat_runs_are_deterministic() {
    let suite = suite();
    let run = || {
        run_shards(
            &suite,
            SchedulerConfig {
                workers: 8,
                shard_policy: ShardPolicy::Stealing,
                sort_params: params(),
                collect_tracks: true,
                ..Default::default()
            },
        )
    };
    let a = run();
    let b = run();
    for (x, y) in a.outputs.iter().zip(&b.outputs) {
        assert_eq!(x.stream_id, y.stream_id);
        assert_eq!(x.rows, y.rows, "stream {} varies across runs", x.stream_id);
    }
}

#[test]
fn every_engine_is_schedulable_with_identical_tracks() {
    // small suite: the xla interpreter engine is much slower per frame
    let suite: Vec<SynthSequence> = (0..4)
        .map(|i| generate_sequence(&SynthConfig::mot15(&format!("E{i}"), 50, 4, i as u64)))
        .collect();
    let reference = serial_rows(&suite);
    for kind in EngineKind::all(2) {
        let report = run_shards(
            &suite,
            SchedulerConfig {
                workers: 2,
                shard_policy: ShardPolicy::Stealing,
                engine: kind,
                sort_params: params(),
                collect_tracks: true,
                ..Default::default()
            },
        );
        for (out, want) in report.outputs.iter().zip(&reference) {
            assert_eq!(
                out.rows, *want,
                "engine {} stream {} diverged from serial Sort",
                kind.label(),
                out.stream_id
            );
        }
    }
}
