//! Session-path equivalence: tracks produced through `TrackingService`
//! sessions must be *byte-identical* (`f64::to_bits`) to a serial
//! `Sort`-style run of the same engine on the same sequences — at
//! 1/2/8 workers, for sessions opened up front, sessions that arrive
//! while others are mid-stream, and sessions reopened on warm
//! (`reset()`) engines.
//!
//! This is the determinism contract that makes runtime admission safe
//! to deploy: *when* a stream attaches, *which* worker it lands on,
//! and *what else* is in flight must never leak into tracking output.

use smalltrack::coordinator::service::{
    ServiceConfig, SessionHandle, SessionParams, TrackingService,
};
use smalltrack::coordinator::PushPolicy;
use smalltrack::data::mot::Sequence;
use smalltrack::data::synth::{generate_sequence, SynthConfig};
use smalltrack::engine::EngineKind;
use smalltrack::sort::{Bbox, SortParams};

fn params() -> SortParams {
    SortParams { timing: false, ..Default::default() }
}

fn session_params(engine: EngineKind) -> SessionParams {
    SessionParams { engine, sort_params: params(), ..Default::default() }
}

/// Lossless service: equivalence demands every frame reaches its engine.
fn service(workers: usize) -> TrackingService {
    TrackingService::start(ServiceConfig {
        workers,
        push_policy: PushPolicy::Block,
        ..Default::default()
    })
    .expect("start service")
}

/// A heterogeneous suite: ragged lengths and object counts so workers
/// hold multiple concurrently-active sessions at 2 and 8 workers.
fn suite(n: usize) -> Vec<Sequence> {
    (0..n)
        .map(|i| {
            let frames = 30 + 45 * (i as u32 % 4);
            let objects = 3 + (i as u32 % 5);
            generate_sequence(&SynthConfig::mot15(&format!("SVC-{i}"), frames, objects, i as u64))
                .sequence
        })
        .collect()
}

/// Serial reference: a fresh engine of the same kind, frames numbered
/// by position (1-based) exactly like session numbering.
fn serial_rows(kind: EngineKind, seq: &Sequence) -> Vec<(u32, u64, Bbox)> {
    let mut engine = kind.build(params()).expect("build engine");
    let mut rows = Vec::new();
    for (i, frame) in seq.frames.iter().enumerate() {
        let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
        for t in engine.update(&boxes) {
            rows.push((i as u32 + 1, t.id, t.bbox));
        }
    }
    rows
}

/// Bit-exact row comparison: ids must match and every bbox coordinate
/// must be the *same f64 bit pattern*, not merely approximately equal.
fn assert_rows_bit_identical(got: &[(u32, u64, Bbox)], want: &[(u32, u64, Bbox)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: row count");
    for (k, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!((g.0, g.1), (w.0, w.1), "{ctx}: row {k} frame/id");
        for (a, b) in [
            (g.2.x1, w.2.x1),
            (g.2.y1, w.2.y1),
            (g.2.x2, w.2.x2),
            (g.2.y2, w.2.y2),
        ] {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{ctx}: row {k} bbox coordinate differs ({a} vs {b})"
            );
        }
    }
}

fn push_all(h: &SessionHandle, seq: &Sequence) {
    for frame in &seq.frames {
        let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
        assert!(h.push_frame(boxes), "push rejected on an open session");
    }
}

#[test]
fn sessions_are_bit_identical_to_serial_at_1_2_8_workers() {
    // batchf32 rides the same contract self-consistently: its session
    // rows must reproduce its own serial rows bit for bit (equality
    // with native is deliberately not part of the f32 tier's contract)
    let suite = suite(10);
    for kind in [EngineKind::Native, EngineKind::Batch, EngineKind::BatchF32] {
        let reference: Vec<_> = suite.iter().map(|s| serial_rows(kind, s)).collect();
        for workers in [1usize, 2, 8] {
            let svc = service(workers);
            // open all sessions first (concurrently live), then feed
            // round-robin so sessions genuinely interleave on workers
            let handles: Vec<SessionHandle> = suite
                .iter()
                .map(|_| svc.open_session(session_params(kind)).expect("open"))
                .collect();
            let mut cursors = vec![0usize; suite.len()];
            loop {
                let mut any = false;
                for (i, seq) in suite.iter().enumerate() {
                    let end = (cursors[i] + 8).min(seq.frames.len());
                    for frame in &seq.frames[cursors[i]..end] {
                        let boxes: Vec<Bbox> =
                            frame.detections.iter().map(|d| d.bbox).collect();
                        handles[i].push_frame(boxes);
                    }
                    any |= end > cursors[i];
                    cursors[i] = end;
                }
                if !any {
                    break;
                }
            }
            for (i, h) in handles.iter().enumerate() {
                let stats = h.join();
                assert_eq!(stats.dropped(), 0, "lossless service must not shed");
                let rows = h.poll_tracks();
                assert_rows_bit_identical(
                    &rows,
                    &reference[i],
                    &format!("engine {} stream {i} w={workers}", kind.label()),
                );
            }
            svc.shutdown();
        }
    }
}

#[test]
fn mid_run_admission_does_not_perturb_inflight_sessions() {
    // wave 1 streams to its midpoint, wave 2 attaches, everything
    // interleaves to completion: every session still bit-matches its
    // serial reference — for native AND batch engines mixed on one
    // service
    let wave1 = suite(6);
    let wave2 = suite(5); // same generator, fresh sessions
    let kinds = [EngineKind::Native, EngineKind::Batch];
    for workers in [2usize, 8] {
        let svc = service(workers);
        let kind_of = |i: usize| kinds[i % kinds.len()];
        let h1: Vec<SessionHandle> = (0..wave1.len())
            .map(|i| svc.open_session(session_params(kind_of(i))).expect("open"))
            .collect();
        // stream wave 1 halfway
        let mut cursors1: Vec<usize> = wave1.iter().map(|s| s.frames.len() / 2).collect();
        for (i, seq) in wave1.iter().enumerate() {
            for frame in &seq.frames[..cursors1[i]] {
                let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
                h1[i].push_frame(boxes);
            }
        }
        // wave 2 arrives mid-run
        let h2: Vec<SessionHandle> = (0..wave2.len())
            .map(|i| svc.open_session(session_params(kind_of(i + 1))).expect("open"))
            .collect();
        // interleave both waves to completion
        let mut cursors2 = vec![0usize; wave2.len()];
        loop {
            let mut any = false;
            for (i, seq) in wave1.iter().enumerate() {
                let end = (cursors1[i] + 8).min(seq.frames.len());
                for frame in &seq.frames[cursors1[i]..end] {
                    let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
                    h1[i].push_frame(boxes);
                }
                any |= end > cursors1[i];
                cursors1[i] = end;
            }
            for (i, seq) in wave2.iter().enumerate() {
                let end = (cursors2[i] + 8).min(seq.frames.len());
                for frame in &seq.frames[cursors2[i]..end] {
                    let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
                    h2[i].push_frame(boxes);
                }
                any |= end > cursors2[i];
                cursors2[i] = end;
            }
            if !any {
                break;
            }
        }
        for (i, h) in h1.iter().enumerate() {
            h.join();
            assert_rows_bit_identical(
                &h.poll_tracks(),
                &serial_rows(kind_of(i), &wave1[i]),
                &format!("wave1 stream {i} w={workers}"),
            );
        }
        for (i, h) in h2.iter().enumerate() {
            h.join();
            assert_rows_bit_identical(
                &h.poll_tracks(),
                &serial_rows(kind_of(i + 1), &wave2[i]),
                &format!("wave2 stream {i} w={workers}"),
            );
        }
        svc.shutdown();
    }
}

#[test]
fn close_then_reopen_reuses_warm_engines_bit_identically() {
    // generation g+1's sessions run on generation g's reset() engines
    // (the single worker forces reuse); output must not change by a bit
    let seqs = suite(3);
    for kind in [EngineKind::Native, EngineKind::Batch, EngineKind::BatchF32] {
        let svc = service(1);
        let mut generations: Vec<Vec<Vec<(u32, u64, Bbox)>>> = Vec::new();
        for _generation in 0..3 {
            let mut outputs = Vec::new();
            for seq in &seqs {
                let h = svc.open_session(session_params(kind)).expect("open");
                push_all(&h, seq);
                h.join();
                outputs.push(h.poll_tracks());
            }
            generations.push(outputs);
        }
        for (g, outputs) in generations.iter().enumerate() {
            for (i, rows) in outputs.iter().enumerate() {
                assert_rows_bit_identical(
                    rows,
                    &serial_rows(kind, &seqs[i]),
                    &format!("engine {} generation {g} stream {i}", kind.label()),
                );
            }
        }
        let m = svc.shutdown();
        assert_eq!(m.sessions_closed, 9, "3 generations x 3 sessions");
    }
}

#[test]
fn serve_wrapper_equals_direct_sessions() {
    // the compatibility wrapper and hand-driven sessions are the same
    // machine: equal track totals on the same inputs
    use smalltrack::coordinator::{serve, Pacing, ServerConfig, VideoStream};
    let seqs = suite(6);
    let direct: u64 = {
        let svc = service(2);
        let handles: Vec<SessionHandle> = seqs
            .iter()
            .map(|s| {
                let h = svc.open_session(session_params(EngineKind::Native)).expect("open");
                push_all(&h, s);
                h
            })
            .collect();
        let total = handles.iter().map(|h| h.join().tracks_out).sum();
        svc.shutdown();
        total
    };
    let streams: Vec<VideoStream> = seqs
        .iter()
        .enumerate()
        .map(|(i, s)| VideoStream::new(i, s.clone(), Pacing::Unpaced))
        .collect();
    let report = serve(
        streams,
        ServerConfig {
            workers: 2,
            push_policy: PushPolicy::Block,
            sort_params: params(),
            ..Default::default()
        },
    );
    assert_eq!(report.dropped, 0);
    assert_eq!(report.tracks_out, direct);
}

#[test]
fn frame_conservation_holds_under_random_slo_schedules() {
    // satellite invariant of the SLO runtime: no frame is ever lost or
    // double-counted. For every randomized schedule of priorities,
    // deadlines, queue capacities, push policies and mid-stream
    // controller sheds:
    //   frames_in == frames_done + dropped_queue + dropped_deadline
    // per session, and the ServiceMetrics totals agree with the sum of
    // the per-session ledgers after every session has retired.
    use smalltrack::coordinator::Slo;
    use smalltrack::proptest_lite::{ensure, run_named, Config};
    use std::time::Duration;

    #[derive(Debug)]
    struct Case {
        workers: usize,
        queue_capacity: usize,
        drop_oldest: bool,
        shed_every: u64,
        // (engine, priority, deadline, frames)
        sessions: Vec<(EngineKind, u8, Option<Duration>, u32)>,
    }

    run_named(
        "slo frame conservation",
        Config { cases: 24, seed: 0xC0_5EED },
        |r| Case {
            workers: 1 + r.below(3) as usize,
            queue_capacity: 2 + r.below(14) as usize,
            drop_oldest: r.chance(0.5),
            shed_every: 3 + r.below(20),
            sessions: (0..1 + r.below(4))
                .map(|_| {
                    let engine =
                        if r.chance(0.5) { EngineKind::Native } else { EngineKind::Batch };
                    let deadline = match r.below(3) {
                        0 => None,
                        // zero: every dequeued frame is already stale
                        1 => Some(Duration::ZERO),
                        // generous: nothing is ever stale
                        _ => Some(Duration::from_secs(3600)),
                    };
                    (engine, 1 + r.below(3) as u8, deadline, 10 + r.below(60) as u32)
                })
                .collect(),
        },
        |case| {
            let svc = TrackingService::start(ServiceConfig {
                workers: case.workers,
                queue_capacity: case.queue_capacity,
                push_policy: if case.drop_oldest {
                    PushPolicy::DropOldest
                } else {
                    PushPolicy::Block
                },
                ..Default::default()
            })
            .map_err(|e| e.to_string())?;
            let handles: Vec<SessionHandle> = case
                .sessions
                .iter()
                .map(|&(engine, priority, deadline, _)| {
                    svc.open_session(SessionParams {
                        engine,
                        sort_params: params(),
                        slo: Slo { deadline, priority, mota_budget: 0.05 },
                        ..Default::default()
                    })
                    .expect("open")
                })
                .collect();
            // round-robin pushes with controller-style sheds mixed in
            let mut pushed = vec![0u64; handles.len()];
            let mut total_pushed = 0u64;
            let max_frames = case.sessions.iter().map(|s| s.3).max().unwrap_or(0);
            for f in 0..max_frames {
                for (i, h) in handles.iter().enumerate() {
                    if u64::from(f) >= u64::from(case.sessions[i].3) {
                        continue;
                    }
                    let x = 10.0 + f64::from(f % 50);
                    assert!(h.push_frame(vec![Bbox::new(x, 10.0, x + 30.0, 80.0)]));
                    pushed[i] += 1;
                    total_pushed += 1;
                    if total_pushed % case.shed_every == 0 {
                        // sheds on a live session: frames drained here
                        // must land in dropped_deadline, not vanish
                        svc.shed_stale(h.id(), 2);
                    }
                }
            }
            let stats: Vec<_> = handles.iter().map(|h| h.join()).collect();
            let m = svc.shutdown();
            for (i, st) in stats.iter().enumerate() {
                ensure(
                    st.frames_in == pushed[i],
                    format!("session {i}: frames_in {} != pushed {}", st.frames_in, pushed[i]),
                )?;
                ensure(
                    st.frames_in == st.frames_done + st.dropped_queue + st.dropped_deadline,
                    format!(
                        "session {i}: {} != {} + {} + {}",
                        st.frames_in, st.frames_done, st.dropped_queue, st.dropped_deadline
                    ),
                )?;
                if !case.drop_oldest {
                    ensure(
                        st.dropped_queue == 0,
                        format!("session {i}: Block policy shed {} frames", st.dropped_queue),
                    )?;
                }
                let judged = st.deadline_hits + st.deadline_misses;
                match case.sessions[i].2 {
                    // no deadline: processed frames are never judged
                    None => ensure(judged == 0, format!("session {i}: judged {judged}"))?,
                    // with a deadline every *processed* frame gets a
                    // hit-or-miss verdict (shed frames are not judged)
                    Some(_) => ensure(
                        judged == st.frames_done,
                        format!("session {i}: judged {judged} != done {}", st.frames_done),
                    )?,
                }
            }
            let sum = |f: fn(&smalltrack::coordinator::SessionStats) -> u64| {
                stats.iter().map(f).sum::<u64>()
            };
            ensure(
                m.frames_done == sum(|s| s.frames_done),
                format!("metrics frames_done {} != session sum", m.frames_done),
            )?;
            ensure(
                m.dropped_queue == sum(|s| s.dropped_queue),
                format!("metrics dropped_queue {} != session sum", m.dropped_queue),
            )?;
            ensure(
                m.dropped_deadline == sum(|s| s.dropped_deadline),
                format!("metrics dropped_deadline {} != session sum", m.dropped_deadline),
            )?;
            ensure(
                total_pushed == m.frames_done + m.dropped_queue + m.dropped_deadline,
                format!(
                    "service conservation: {total_pushed} != {} + {} + {}",
                    m.frames_done, m.dropped_queue, m.dropped_deadline
                ),
            )
        },
    );
}

#[test]
fn all_engines_run_through_sessions() {
    // broader but lighter: every backend (incl. strong, the xla
    // interpreter and the f32 tier) serves through sessions with
    // serial-identical rows
    let seq = &suite(1)[0];
    let svc = service(2);
    for kind in EngineKind::all_tiers(2) {
        let h = svc.open_session(session_params(kind)).expect("open");
        push_all(&h, seq);
        h.join();
        assert_rows_bit_identical(
            &h.poll_tracks(),
            &serial_rows(kind, seq),
            &format!("engine {}", kind.label()),
        );
    }
    svc.shutdown();
}
