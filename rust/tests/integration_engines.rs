//! Engine equivalence: every f64 [`TrackerEngine`] backend must emit
//! identical track ids and boxes on shared deterministic input.
//!
//! This is the contract that makes the backends interchangeable behind
//! the coordinator: `native` is the reference; `batch` runs the exact
//! same scalar sequence over explicit SIMD lane blocks (asserted
//! *byte-identical*, `f64::to_bits`, at every lane width — lane width
//! is an execution detail, never a numeric one); `strong` runs the
//! same math under fork-join parallelism; `xla` runs it through the
//! batched tracker-bank kernels. The bank's reference interpreter
//! reuses the native Kalman kernels, so agreement is expected to be
//! bitwise on the state path (asserted here at 1e-9 to stay robust if
//! the compiled PJRT backend — dense formulation, ~1e-9 agreement — is
//! swapped in).
//!
//! The `batchf32` tier is exempt from cross-engine equality by design
//! (reduced precision); it is pinned to determinism and scheduler
//! self-consistency instead: serial f32 rows are the reference, and
//! the sharded scheduler must reproduce them bit for bit.

use smalltrack::coordinator::scheduler::{run_shards, SchedulerConfig, ShardPolicy};
use smalltrack::data::synth::{generate_sequence, SynthConfig, SynthSequence};
use smalltrack::engine::{EngineKind, TrackerEngine};
use smalltrack::linalg::LaneWidth;
use smalltrack::sort::{BatchSort, Bbox, SortParams, Track};

fn params() -> SortParams {
    SortParams { timing: false, ..Default::default() }
}

/// Per-frame sorted track outputs for one engine over a sequence.
fn track_all(engine: &mut dyn TrackerEngine, synth: &SynthSequence) -> Vec<Vec<Track>> {
    let mut out = Vec::with_capacity(synth.sequence.frames.len());
    let mut boxes: Vec<Bbox> = Vec::new();
    for frame in &synth.sequence.frames {
        boxes.clear();
        boxes.extend(frame.detections.iter().map(|d| d.bbox));
        let mut tracks = engine.update(&boxes).to_vec();
        tracks.sort_by_key(|t| t.id);
        out.push(tracks);
    }
    out
}

fn assert_equivalent(name: &str, got: &[Vec<Track>], want: &[Vec<Track>]) {
    assert_eq!(got.len(), want.len());
    for (k, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.iter().map(|t| t.id).collect::<Vec<_>>(),
            w.iter().map(|t| t.id).collect::<Vec<_>>(),
            "{name}: frame {k} ids diverge"
        );
        for (a, b) in g.iter().zip(w) {
            for (x, y) in a.bbox.to_array().iter().zip(b.bbox.to_array()) {
                assert!(
                    (x - y).abs() < 1e-9,
                    "{name}: frame {k} id {} box {} vs {}",
                    a.id,
                    x,
                    y
                );
            }
        }
    }
}

#[test]
fn all_engines_emit_identical_tracks() {
    // 8 objects keeps the run inside the bank's 16-slot capacity
    let synth = generate_sequence(&SynthConfig::mot15("ENGEQ", 200, 8, 23));
    let mut native = EngineKind::Native.build(params()).expect("native");
    let reference = track_all(&mut *native, &synth);
    assert!(
        reference.iter().map(Vec::len).sum::<usize>() > 200,
        "reference run produced too few tracks to be meaningful"
    );
    for kind in [EngineKind::Batch, EngineKind::Strong { threads: 3 }, EngineKind::Xla] {
        let mut engine = kind.build(params()).expect("build");
        let got = track_all(&mut *engine, &synth);
        assert_equivalent(kind.label(), &got, &reference);
    }
}

/// Per-frame track outputs with exact bit patterns (no tolerance).
fn assert_byte_identical(name: &str, got: &[Vec<Track>], want: &[Vec<Track>]) {
    assert_eq!(got.len(), want.len());
    for (k, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.len(), w.len(), "{name}: frame {k} track count");
        for (a, b) in g.iter().zip(w) {
            assert_eq!(a.id, b.id, "{name}: frame {k} ids diverge");
            assert_eq!(
                a.bbox.to_array().map(f64::to_bits),
                b.bbox.to_array().map(f64::to_bits),
                "{name}: frame {k} id {} bit pattern diverges",
                a.id
            );
        }
    }
}

#[test]
fn batch_is_byte_identical_to_native_on_randomized_streams() {
    // the batch engine replays the native scalar op sequence over SoA
    // lanes, so agreement must be exact to the last bit — across many
    // randomized streams with births, dropouts (det_prob < 1), false
    // positives and varying object counts
    for (i, &(frames, objects, seed)) in [
        (200u32, 8u32, 23u64),
        (150, 3, 101),
        (150, 13, 7),
        (80, 1, 55),
        (300, 6, 2024),
    ]
    .iter()
    .enumerate()
    {
        let synth = generate_sequence(&SynthConfig::mot15(&format!("BB-{i}"), frames, objects, seed));
        let mut native = EngineKind::Native.build(params()).expect("native");
        let mut batch = EngineKind::Batch.build(params()).expect("batch");
        let want = track_all(&mut *native, &synth);
        let got = track_all(&mut *batch, &synth);
        assert_byte_identical(&format!("batch stream {i}"), &got, &want);
    }
}

#[test]
fn every_lane_width_is_byte_identical_to_native_on_randomized_streams() {
    // lanes are independent trackers: widening the blocks from scalar
    // to 4- or 8-wide must not move a single bit of any track
    for (i, &(frames, objects, seed)) in
        [(200u32, 8u32, 23u64), (150, 13, 7), (300, 6, 2024)].iter().enumerate()
    {
        let synth = generate_sequence(&SynthConfig::mot15(&format!("LW-{i}"), frames, objects, seed));
        let mut native = EngineKind::Native.build(params()).expect("native");
        let want = track_all(&mut *native, &synth);
        for width in LaneWidth::ALL {
            let mut batch = BatchSort::<f64>::with_lane_width(params(), width);
            let got = track_all(&mut batch, &synth);
            assert_byte_identical(&format!("stream {i} width {}", width.label()), &got, &want);
        }
    }
}

#[test]
fn batchf32_is_deterministic_and_tracks_native_closely() {
    // the f32 tier gives up bit-identity to native, not determinism:
    // two runs must agree bit for bit, and stay within loose float
    // tolerance of the native reference on a benign stream
    let synth = generate_sequence(&SynthConfig::mot15("F32D", 150, 6, 77));
    let mut a = EngineKind::BatchF32.build(params()).expect("batchf32");
    let mut b = EngineKind::BatchF32.build(params()).expect("batchf32");
    let ra = track_all(&mut *a, &synth);
    let rb = track_all(&mut *b, &synth);
    assert_byte_identical("batchf32 determinism", &ra, &rb);
    // vs native: lifecycle near-ties may legitimately resolve
    // differently in reduced precision, so compare in aggregate (track
    // volume) plus box agreement on the frames whose id sets match —
    // which should be essentially all of them
    let mut native = EngineKind::Native.build(params()).expect("native");
    let want = track_all(&mut *native, &synth);
    let (total, native_total): (usize, usize) =
        (ra.iter().map(Vec::len).sum(), want.iter().map(Vec::len).sum());
    let volume_gap = (total as f64 - native_total as f64).abs() / native_total as f64;
    assert!(volume_gap < 0.01, "batchf32 track volume diverged: {total} vs {native_total}");
    let mut compared = 0usize;
    for (k, (g, w)) in ra.iter().zip(&want).enumerate() {
        let ids = |v: &[Track]| v.iter().map(|t| t.id).collect::<Vec<_>>();
        if ids(g) != ids(w) {
            continue;
        }
        compared += 1;
        for (a, b) in g.iter().zip(w) {
            for (x, y) in a.bbox.to_array().iter().zip(b.bbox.to_array()) {
                let rel = (x - y).abs() / x.abs().max(1.0);
                assert!(rel < 1e-2, "frame {k} id {} box {x} vs {y}", a.id);
            }
        }
    }
    assert!(
        compared * 10 >= want.len() * 9,
        "batchf32 id sets matched native on only {compared}/{} frames",
        want.len()
    );
}

#[test]
fn batch_is_byte_identical_under_sharded_scheduler() {
    // the scheduler must be a pure throughput transform for the batch
    // engine too: pinned/stealing shards at 1, 2 and 8 workers emit the
    // same rows as a serial native run, bit for bit
    let suite: Vec<SynthSequence> = (0..6)
        .map(|i| {
            generate_sequence(&SynthConfig::mot15(
                &format!("BSCH-{i}"),
                60 + 30 * (i as u32 % 3),
                3 + (i as u32 % 4),
                i as u64,
            ))
        })
        .collect();
    // serial native reference rows, one fresh engine per stream
    let reference: Vec<Vec<(u32, u64, Bbox)>> = suite
        .iter()
        .map(|s| {
            let mut engine = EngineKind::Native.build(params()).expect("build");
            let mut rows = Vec::new();
            let mut boxes: Vec<Bbox> = Vec::new();
            for frame in &s.sequence.frames {
                boxes.clear();
                boxes.extend(frame.detections.iter().map(|d| d.bbox));
                for t in engine.update(&boxes) {
                    rows.push((frame.index, t.id, t.bbox));
                }
            }
            rows
        })
        .collect();
    for workers in [1usize, 2, 8] {
        for policy in [ShardPolicy::Pinned, ShardPolicy::Stealing] {
            let report = run_shards(
                &suite,
                SchedulerConfig {
                    workers,
                    shard_policy: policy,
                    engine: EngineKind::Batch,
                    sort_params: params(),
                    collect_tracks: true,
                    ..Default::default()
                },
            );
            assert_eq!(report.outputs.len(), suite.len());
            for (out, want) in report.outputs.iter().zip(&reference) {
                assert_eq!(out.rows.len(), want.len());
                for ((fa, ia, ba), (fb, ib, bb)) in out.rows.iter().zip(want) {
                    assert_eq!((fa, ia), (fb, ib), "stream {} w={workers}", out.stream_id);
                    assert_eq!(
                        ba.to_array().map(f64::to_bits),
                        bb.to_array().map(f64::to_bits),
                        "stream {} w={workers} {} diverged from serial native",
                        out.stream_id,
                        policy.label()
                    );
                }
            }
        }
    }
}

#[test]
fn batchf32_is_byte_identical_to_its_serial_self_under_sharded_scheduler() {
    // the f32 tier's contract under the scheduler: not equality with
    // native (reduced precision), but exact reproduction of its own
    // serial rows at any worker count and shard policy
    let suite: Vec<SynthSequence> = (0..4)
        .map(|i| {
            generate_sequence(&SynthConfig::mot15(
                &format!("F32SCH-{i}"),
                60 + 30 * (i as u32 % 3),
                3 + (i as u32 % 4),
                i as u64,
            ))
        })
        .collect();
    // serial batchf32 reference rows, one fresh engine per stream
    let reference: Vec<Vec<(u32, u64, Bbox)>> = suite
        .iter()
        .map(|s| {
            let mut engine = EngineKind::BatchF32.build(params()).expect("build");
            let mut rows = Vec::new();
            let mut boxes: Vec<Bbox> = Vec::new();
            for frame in &s.sequence.frames {
                boxes.clear();
                boxes.extend(frame.detections.iter().map(|d| d.bbox));
                for t in engine.update(&boxes) {
                    rows.push((frame.index, t.id, t.bbox));
                }
            }
            rows
        })
        .collect();
    for workers in [1usize, 2, 8] {
        for policy in [ShardPolicy::Pinned, ShardPolicy::Stealing] {
            let report = run_shards(
                &suite,
                SchedulerConfig {
                    workers,
                    shard_policy: policy,
                    engine: EngineKind::BatchF32,
                    sort_params: params(),
                    collect_tracks: true,
                    ..Default::default()
                },
            );
            assert_eq!(report.outputs.len(), suite.len());
            for (out, want) in report.outputs.iter().zip(&reference) {
                assert_eq!(out.rows.len(), want.len());
                for ((fa, ia, ba), (fb, ib, bb)) in out.rows.iter().zip(want) {
                    assert_eq!((fa, ia), (fb, ib), "stream {} w={workers}", out.stream_id);
                    assert_eq!(
                        ba.to_array().map(f64::to_bits),
                        bb.to_array().map(f64::to_bits),
                        "stream {} w={workers} {} diverged from serial batchf32",
                        out.stream_id,
                        policy.label()
                    );
                }
            }
        }
    }
}

/// Bit-exact comparison for service rows `(frame, id, bbox)`.
fn assert_rows_bit_identical(got: &[(u32, u64, Bbox)], want: &[(u32, u64, Bbox)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: row count");
    for (k, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!((g.0, g.1), (w.0, w.1), "{ctx}: row {k} frame/id");
        assert_eq!(
            g.2.to_array().map(f64::to_bits),
            w.2.to_array().map(f64::to_bits),
            "{ctx}: row {k} bbox bits diverge"
        );
    }
}

/// Serial unmigrated reference: rows numbered 1-based like sessions.
fn serial_session_rows(kind: EngineKind, synth: &SynthSequence) -> Vec<(u32, u64, Bbox)> {
    let mut engine = kind.build(params()).expect("build");
    let mut rows = Vec::new();
    let mut boxes: Vec<Bbox> = Vec::new();
    for (k, frame) in synth.sequence.frames.iter().enumerate() {
        boxes.clear();
        boxes.extend(frame.detections.iter().map(|d| d.bbox));
        for t in engine.update(&boxes) {
            rows.push((k as u32 + 1, t.id, t.bbox));
        }
    }
    rows
}

#[test]
fn mid_stream_migration_is_byte_identical_to_never_migrating() {
    // the warm-handoff promise that makes the controller's tier moves
    // safe: for f64↔f64 pairs (native/batch share exact scalar math) a
    // session migrated at an arbitrary mid-stream frame must emit the
    // same rows, bit for bit, as a serial run that never migrates — at
    // 1, 2 and 8 workers, with every session's handoff staged while
    // the others are still in flight
    use smalltrack::coordinator::service::{
        ServiceConfig, SessionHandle, SessionParams, TrackingService,
    };
    use smalltrack::coordinator::PushPolicy;

    let suite: Vec<SynthSequence> = (0..4)
        .map(|i| {
            generate_sequence(&SynthConfig::mot15(
                &format!("MIG-{i}"),
                90 + 20 * (i as u32 % 3),
                3 + (i as u32 % 4),
                40 + i as u64,
            ))
        })
        .collect();
    let reference: Vec<Vec<(u32, u64, Bbox)>> =
        suite.iter().map(|s| serial_session_rows(EngineKind::Native, s)).collect();
    for workers in [1usize, 2, 8] {
        let svc = TrackingService::start(ServiceConfig {
            workers,
            push_policy: PushPolicy::Block,
            ..Default::default()
        })
        .expect("start service");
        // alternate the starting tier; each session later migrates to
        // the opposite f64 tier at its own cut point
        let from = |i: usize| if i % 2 == 0 { EngineKind::Native } else { EngineKind::Batch };
        let to = |i: usize| if i % 2 == 0 { EngineKind::Batch } else { EngineKind::Native };
        let handles: Vec<SessionHandle> = (0..suite.len())
            .map(|i| {
                svc.open_session(SessionParams {
                    engine: from(i),
                    sort_params: params(),
                    ..Default::default()
                })
                .expect("open")
            })
            .collect();
        // ragged cut points: early, mid and late handoffs in one run
        let cuts: Vec<usize> = suite
            .iter()
            .enumerate()
            .map(|(i, s)| s.sequence.frames.len() * (i + 1) / (suite.len() + 1))
            .collect();
        let push_range = |i: usize, lo: usize, hi: usize| {
            for frame in &suite[i].sequence.frames[lo..hi] {
                let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
                assert!(handles[i].push_frame(boxes));
            }
        };
        // interleave first halves so sessions are concurrently live,
        // then stage every migration, then interleave the remainders
        let mut cursors = vec![0usize; suite.len()];
        loop {
            let mut any = false;
            for i in 0..suite.len() {
                let end = (cursors[i] + 8).min(cuts[i]);
                push_range(i, cursors[i], end);
                any |= end > cursors[i];
                cursors[i] = end;
            }
            if !any {
                break;
            }
        }
        for (i, h) in handles.iter().enumerate() {
            h.migrate_engine(to(i)).expect("stage migration");
        }
        loop {
            let mut any = false;
            for i in 0..suite.len() {
                let end = (cursors[i] + 8).min(suite[i].sequence.frames.len());
                push_range(i, cursors[i], end);
                any |= end > cursors[i];
                cursors[i] = end;
            }
            if !any {
                break;
            }
        }
        for (i, h) in handles.iter().enumerate() {
            let st = h.join();
            assert_eq!(st.migrations, 1, "stream {i} w={workers}: handoff not applied");
            assert_eq!(h.engine_kind(), to(i), "stream {i} w={workers}: wrong tier after join");
            assert_rows_bit_identical(
                &h.poll_tracks(),
                &reference[i],
                &format!("{}→{} stream {i} w={workers}", from(i).label(), to(i).label()),
            );
        }
        svc.shutdown();
    }
}

#[test]
fn f32_round_trip_migration_is_deterministic_and_inside_the_lab_budget() {
    // the controller's demote/promote cycle: batch→batchf32 under
    // overload, back to batch when headroom returns. Bit-identity with
    // the unmigrated f64 run is forfeited in the f32 segment (and the
    // divergence legitimately persists after promotion — tracker state
    // carries its history), so the contract is the one the lab gates:
    // run-to-run bit determinism, and MOTA within the precision tier's
    // budget of the pure-f64 run
    use smalltrack::coordinator::service::{ServiceConfig, SessionParams, TrackingService};
    use smalltrack::coordinator::PushPolicy;
    use smalltrack::lab::GateConfig;
    use smalltrack::sort::quality::{evaluate, EvalFrame};
    use std::collections::HashMap;

    let synth = generate_sequence(&SynthConfig::mot15("F32MIG", 150, 6, 77));
    let frames = synth.sequence.frames.len();
    let run_round_trip = || -> (Vec<(u32, u64, Bbox)>, u64) {
        let svc = TrackingService::start(ServiceConfig {
            workers: 2,
            push_policy: PushPolicy::Block,
            ..Default::default()
        })
        .expect("start service");
        let h = svc
            .open_session(SessionParams {
                engine: EngineKind::Batch,
                sort_params: params(),
                ..Default::default()
            })
            .expect("open");
        for (k, frame) in synth.sequence.frames.iter().enumerate() {
            // thirds: f64 warmup, f32 overload segment, f64 again
            if k == frames / 3 {
                h.migrate_engine(EngineKind::BatchF32).expect("demote");
            }
            if k == 2 * frames / 3 {
                h.migrate_engine(EngineKind::Batch).expect("promote");
            }
            let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
            assert!(h.push_frame(boxes));
        }
        let st = h.join();
        assert_eq!(h.engine_kind(), EngineKind::Batch, "round trip must land on f64");
        let rows = h.poll_tracks();
        svc.shutdown();
        (rows, st.migrations)
    };
    let (ra, ma) = run_round_trip();
    let (rb, mb) = run_round_trip();
    assert_eq!((ma, mb), (2, 2), "both handoffs must apply in both runs");
    assert_rows_bit_identical(&ra, &rb, "f32 round trip determinism");
    // quality: migrated rows vs the pure-f64 serial run, judged on the
    // synth ground truth under the lab's own precision-tier budget
    let mota = |rows: &[(u32, u64, Bbox)]| {
        let mut gt_by_frame: HashMap<u32, Vec<(u64, Bbox)>> = HashMap::new();
        for t in &synth.ground_truth {
            for &(f, b) in &t.boxes {
                gt_by_frame.entry(f).or_default().push((t.id, b));
            }
        }
        let mut tracks_by_frame: HashMap<u32, Vec<(u64, Bbox)>> = HashMap::new();
        for &(seq_no, tid, b) in rows {
            tracks_by_frame.entry(seq_no - 1).or_default().push((tid, b));
        }
        let eval: Vec<EvalFrame> = (0..frames as u32)
            .map(|f| EvalFrame {
                gt: gt_by_frame.remove(&f).unwrap_or_default(),
                tracks: tracks_by_frame.remove(&f).unwrap_or_default(),
            })
            .collect();
        evaluate(&eval, 0.5).mota()
    };
    let pure = mota(&serial_session_rows(EngineKind::Batch, &synth));
    let migrated = mota(&ra);
    let budget = GateConfig::default().f32_mota_delta;
    assert!(
        migrated >= pure - budget,
        "round-trip MOTA {migrated:.4} trails pure f64 {pure:.4} beyond the budget {budget}"
    );
}

#[test]
fn equivalence_holds_across_reset() {
    // engines reused via reset() (the worker-pool pattern) must match
    // fresh engines exactly
    let a = generate_sequence(&SynthConfig::mot15("ENGR-A", 80, 6, 5));
    let b = generate_sequence(&SynthConfig::mot15("ENGR-B", 80, 6, 6));
    for kind in EngineKind::all(2) {
        let mut reused = kind.build(params()).expect("build");
        track_all(&mut *reused, &a);
        reused.reset();
        let got = track_all(&mut *reused, &b);
        let mut fresh = kind.build(params()).expect("build");
        let want = track_all(&mut *fresh, &b);
        assert_equivalent(kind.label(), &got, &want);
    }
}

#[test]
fn equivalence_with_empty_and_bursty_frames() {
    // hand-built stress: birth, dropout (coast), reacquire, death
    let b = |x: f64, y: f64| Bbox::new(x, y, x + 30.0, y + 70.0);
    let frames: Vec<Vec<Bbox>> = vec![
        vec![b(10.0, 10.0), b(500.0, 300.0)],
        vec![b(13.0, 11.0), b(498.0, 302.0)],
        vec![b(16.0, 12.0), b(496.0, 304.0)],
        vec![b(19.0, 13.0)], // second object drops out
        vec![b(22.0, 14.0), b(492.0, 308.0)], // reacquired within max_age
        vec![],              // everything coasts
        vec![b(28.0, 16.0)],
        vec![b(31.0, 17.0), b(900.0, 900.0)], // newcomer
        vec![b(34.0, 18.0), b(903.0, 901.0)],
        vec![b(37.0, 19.0), b(906.0, 902.0)],
        vec![b(40.0, 20.0), b(909.0, 903.0)],
    ];
    let run = |engine: &mut dyn TrackerEngine| -> Vec<Vec<Track>> {
        frames
            .iter()
            .map(|boxes| {
                let mut t = engine.update(boxes).to_vec();
                t.sort_by_key(|t| t.id);
                t
            })
            .collect()
    };
    let mut native = EngineKind::Native.build(params()).expect("native");
    let want = run(&mut *native);
    for kind in [EngineKind::Batch, EngineKind::Strong { threads: 2 }, EngineKind::Xla] {
        let mut engine = kind.build(params()).expect("build");
        let got = run(&mut *engine);
        assert_equivalent(kind.label(), &got, &want);
    }
}
