//! Engine equivalence: all three [`TrackerEngine`] backends must emit
//! identical track ids and boxes on shared deterministic input.
//!
//! This is the contract that makes the backends interchangeable behind
//! the coordinator: `native` is the reference; `strong` runs the same
//! math under fork-join parallelism; `xla` runs it through the batched
//! tracker-bank kernels. The bank's reference interpreter reuses the
//! native Kalman kernels, so agreement is expected to be bitwise on the
//! state path (asserted here at 1e-9 to stay robust if the compiled
//! PJRT backend — dense formulation, ~1e-9 agreement — is swapped in).

use smalltrack::data::synth::{generate_sequence, SynthConfig, SynthSequence};
use smalltrack::engine::{EngineKind, TrackerEngine};
use smalltrack::sort::{Bbox, SortParams, Track};

fn params() -> SortParams {
    SortParams { timing: false, ..Default::default() }
}

/// Per-frame sorted track outputs for one engine over a sequence.
fn track_all(engine: &mut dyn TrackerEngine, synth: &SynthSequence) -> Vec<Vec<Track>> {
    let mut out = Vec::with_capacity(synth.sequence.frames.len());
    let mut boxes: Vec<Bbox> = Vec::new();
    for frame in &synth.sequence.frames {
        boxes.clear();
        boxes.extend(frame.detections.iter().map(|d| d.bbox));
        let mut tracks = engine.update(&boxes).to_vec();
        tracks.sort_by_key(|t| t.id);
        out.push(tracks);
    }
    out
}

fn assert_equivalent(name: &str, got: &[Vec<Track>], want: &[Vec<Track>]) {
    assert_eq!(got.len(), want.len());
    for (k, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.iter().map(|t| t.id).collect::<Vec<_>>(),
            w.iter().map(|t| t.id).collect::<Vec<_>>(),
            "{name}: frame {k} ids diverge"
        );
        for (a, b) in g.iter().zip(w) {
            for (x, y) in a.bbox.to_array().iter().zip(b.bbox.to_array()) {
                assert!(
                    (x - y).abs() < 1e-9,
                    "{name}: frame {k} id {} box {} vs {}",
                    a.id,
                    x,
                    y
                );
            }
        }
    }
}

#[test]
fn all_engines_emit_identical_tracks() {
    // 8 objects keeps the run inside the bank's 16-slot capacity
    let synth = generate_sequence(&SynthConfig::mot15("ENGEQ", 200, 8, 23));
    let mut native = EngineKind::Native.build(params()).expect("native");
    let reference = track_all(&mut *native, &synth);
    assert!(
        reference.iter().map(Vec::len).sum::<usize>() > 200,
        "reference run produced too few tracks to be meaningful"
    );
    for kind in [EngineKind::Strong { threads: 3 }, EngineKind::Xla] {
        let mut engine = kind.build(params()).expect("build");
        let got = track_all(&mut *engine, &synth);
        assert_equivalent(kind.label(), &got, &reference);
    }
}

#[test]
fn equivalence_holds_across_reset() {
    // engines reused via reset() (the worker-pool pattern) must match
    // fresh engines exactly
    let a = generate_sequence(&SynthConfig::mot15("ENGR-A", 80, 6, 5));
    let b = generate_sequence(&SynthConfig::mot15("ENGR-B", 80, 6, 6));
    for kind in EngineKind::all(2) {
        let mut reused = kind.build(params()).expect("build");
        track_all(&mut *reused, &a);
        reused.reset();
        let got = track_all(&mut *reused, &b);
        let mut fresh = kind.build(params()).expect("build");
        let want = track_all(&mut *fresh, &b);
        assert_equivalent(kind.label(), &got, &want);
    }
}

#[test]
fn equivalence_with_empty_and_bursty_frames() {
    // hand-built stress: birth, dropout (coast), reacquire, death
    let b = |x: f64, y: f64| Bbox::new(x, y, x + 30.0, y + 70.0);
    let frames: Vec<Vec<Bbox>> = vec![
        vec![b(10.0, 10.0), b(500.0, 300.0)],
        vec![b(13.0, 11.0), b(498.0, 302.0)],
        vec![b(16.0, 12.0), b(496.0, 304.0)],
        vec![b(19.0, 13.0)], // second object drops out
        vec![b(22.0, 14.0), b(492.0, 308.0)], // reacquired within max_age
        vec![],              // everything coasts
        vec![b(28.0, 16.0)],
        vec![b(31.0, 17.0), b(900.0, 900.0)], // newcomer
        vec![b(34.0, 18.0), b(903.0, 901.0)],
        vec![b(37.0, 19.0), b(906.0, 902.0)],
        vec![b(40.0, 20.0), b(909.0, 903.0)],
    ];
    let run = |engine: &mut dyn TrackerEngine| -> Vec<Vec<Track>> {
        frames
            .iter()
            .map(|boxes| {
                let mut t = engine.update(boxes).to_vec();
                t.sort_by_key(|t| t.id);
                t
            })
            .collect()
    };
    let mut native = EngineKind::Native.build(params()).expect("native");
    let want = run(&mut *native);
    for kind in [EngineKind::Strong { threads: 2 }, EngineKind::Xla] {
        let mut engine = kind.build(params()).expect("build");
        let got = run(&mut *engine);
        assert_equivalent(kind.label(), &got, &want);
    }
}
