//! Ingest integration: the checked-in fixtures are exact fixed points
//! of the canonical writers (byte identity), conversion between
//! formats is lossless (box bits survive MOT ⇄ COCO), auto-detection
//! classifies every fixture (returning typed errors — never panics —
//! on the ambiguous/garbage ones), the seeded fuzz harness holds its
//! contract for the pinned 10k iterations, and tracking a real file
//! is bit-identical across the native and batch engines, in-process
//! and through the `track --input` CLI.
//!
//! Fixtures live in `rust/tests/fixtures/ingest/` and are regenerated
//! by `make_fixtures.py` there; `make ingest-smoke` re-serializes them
//! through the convert CLI and pins the bytes with
//! `git diff --exit-code`.

use smalltrack::data::ingest::{
    self, detect_format, fuzz, parse_coco, parse_mot_det, parse_mot_gt, write_coco,
    write_mot_det, write_mot_gt, Confidence, ParseMode, SourceFormat,
};
use smalltrack::engine::EngineKind;
use smalltrack::sort::{Bbox, SortParams};
use std::path::PathBuf;
use std::process::Command;

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/ingest")
}

fn fixture(name: &str) -> String {
    let p = fixture_dir().join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {p:?}: {e}"))
}

#[test]
fn det_fixture_is_a_byte_exact_writer_fixed_point() {
    let text = fixture("tiny.det.txt");
    let ir = parse_mot_det(&text, "tiny", ParseMode::Strict).expect("strict parse");
    assert_eq!(write_mot_det(&ir), text, "det -> IR -> det must be byte-identical");
    assert_eq!(ir.n_frames(), 60);
    assert_eq!(ir.n_entries(), 322);
    assert_eq!(ingest::validate(&ir).issues.len(), 0, "fixture must validate clean");
}

#[test]
fn gt_fixture_is_a_byte_exact_writer_fixed_point() {
    let text = fixture("tiny.gt.txt");
    let ir = parse_mot_gt(&text, "tiny", ParseMode::Strict).expect("strict parse");
    assert_eq!(write_mot_gt(&ir), text, "gt -> IR -> gt must be byte-identical");
    assert_eq!(ir.n_frames(), 60);
    assert_eq!(ir.n_entries(), 336);
    let mut ids: Vec<u64> =
        ir.frames.iter().flat_map(|f| f.entries.iter().filter_map(|e| e.track_id)).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids, vec![1, 2, 3, 4, 5, 6]);
    assert_eq!(ingest::validate(&ir).issues.len(), 0, "fixture must validate clean");
}

#[test]
fn coco_fixture_is_a_byte_exact_writer_fixed_point() {
    let text = fixture("tiny.coco.json");
    let ir = parse_coco(&text, "tiny", ParseMode::Strict).expect("strict parse");
    assert_eq!(write_coco(&ir), text, "coco -> IR -> coco must be byte-identical");
    assert_eq!(ir.n_frames(), 60);
    assert_eq!(ir.n_entries(), 322);
}

#[test]
fn mot_to_coco_conversion_is_lossless_and_byte_exact() {
    // the COCO fixture was generated from the det fixture, so the
    // canonical writers must map each onto the other exactly
    let det = fixture("tiny.det.txt");
    let coco = fixture("tiny.coco.json");
    let det_ir = parse_mot_det(&det, "tiny", ParseMode::Strict).unwrap();
    let coco_ir = parse_coco(&coco, "tiny", ParseMode::Strict).unwrap();
    assert_eq!(write_coco(&det_ir), coco, "det -> IR -> coco must reproduce the fixture");
    assert_eq!(write_mot_det(&coco_ir), det, "coco -> IR -> det must reproduce the fixture");
    // boxes and scores survive the round trip bit-for-bit
    assert_eq!(det_ir.n_frames(), coco_ir.n_frames());
    for (df, cf) in det_ir.frames.iter().zip(&coco_ir.frames) {
        assert_eq!(df.index, cf.index);
        assert_eq!(df.entries.len(), cf.entries.len(), "frame {}", df.index);
        for (de, ce) in df.entries.iter().zip(&cf.entries) {
            for k in 0..4 {
                assert_eq!(
                    de.ltwh[k].to_bits(),
                    ce.ltwh[k].to_bits(),
                    "frame {} ltwh[{k}]",
                    df.index
                );
            }
            assert_eq!(
                de.score.map(f64::to_bits),
                ce.score.map(f64::to_bits),
                "frame {}",
                df.index
            );
        }
    }
}

#[test]
fn auto_detect_classifies_every_fixture_and_rejects_junk_with_typed_errors() {
    let cases = [
        ("tiny.det.txt", Some(SourceFormat::MotDet)),
        ("tiny.gt.txt", Some(SourceFormat::MotGt)),
        ("tiny.coco.json", Some(SourceFormat::Coco)),
        ("ambiguous.txt", None),
        ("garbage.txt", None),
    ];
    for (name, want) in cases {
        let text = fixture(name);
        match (detect_format(&text), want) {
            (Ok(guess), Some(f)) => {
                assert_eq!(guess.format, f, "{name}: {}", guess.detail);
                assert_eq!(guess.confidence, Confidence::High, "{name}: {}", guess.detail);
            }
            (Err(e), None) => {
                // typed error with a human-readable verdict, no panic
                assert!(!e.to_string().is_empty(), "{name}");
            }
            (got, _) => panic!("{name}: unexpected detect verdict {got:?}"),
        }
        // load_path agrees: parses the recognized formats, surfaces
        // the typed error for the rest
        let loaded = ingest::load_path(&fixture_dir().join(name), None, ParseMode::Strict);
        assert_eq!(loaded.is_ok(), want.is_some(), "{name}");
    }
}

#[test]
fn fuzz_contract_holds_for_the_pinned_ten_thousand_iterations() {
    // same seed the CI job runs; any panic or canonical-write drift
    // inside the harness fails this test
    let stats = fuzz::run(7, 10_000);
    assert_eq!(stats.iterations, 10_000);
    assert!(stats.total_ok() > 0, "{stats:?}");
    assert!(stats.total_rejected() > 0, "{stats:?}");
    assert!(stats.roundtrips > 0, "{stats:?}");
    assert!(stats.detect_ok + stats.detect_rejected == 10_000, "{stats:?}");
    // determinism: the tally (not just the verdict) reproduces
    assert_eq!(stats, fuzz::run(7, 10_000), "same seed must give identical stats");
}

/// Track the det fixture with one engine, returning the output rows.
fn track_fixture(kind: EngineKind) -> Vec<(u32, u64, Bbox)> {
    let (ir, _) =
        ingest::load_path(&fixture_dir().join("tiny.det.txt"), None, ParseMode::Strict).unwrap();
    let seq = ir.to_sequence();
    let mut engine = kind.build(SortParams { timing: false, ..Default::default() }).unwrap();
    let mut rows = Vec::new();
    let mut boxes = Vec::new();
    for frame in &seq.frames {
        boxes.clear();
        boxes.extend(frame.detections.iter().map(|d| d.bbox));
        for t in engine.update(&boxes) {
            rows.push((frame.index, t.id, t.bbox));
        }
    }
    rows
}

#[test]
fn native_and_batch_tracks_are_bit_identical_on_the_real_fixture() {
    let native = track_fixture(EngineKind::Native);
    let batch = track_fixture(EngineKind::Batch);
    assert!(!native.is_empty(), "fixture must produce tracks");
    assert_eq!(native.len(), batch.len());
    for (a, b) in native.iter().zip(&batch) {
        assert_eq!((a.0, a.1), (b.0, b.1));
        assert_eq!(a.2.x1.to_bits(), b.2.x1.to_bits());
        assert_eq!(a.2.y1.to_bits(), b.2.y1.to_bits());
        assert_eq!(a.2.x2.to_bits(), b.2.x2.to_bits());
        assert_eq!(a.2.y2.to_bits(), b.2.y2.to_bits());
    }
    // and both score sanely against the fixture's ground truth
    let (gt, _) = ingest::load_path(
        &fixture_dir().join("tiny.gt.txt"),
        Some(SourceFormat::MotGt),
        ParseMode::Strict,
    )
    .unwrap();
    let m = ingest::score_tracks(&gt, &native, 0.5);
    assert_eq!(m.n_gt, 336);
    assert!(m.mota() > 0.2, "implausible fixture MOTA {}", m.mota());
}

#[test]
fn track_input_cli_runs_the_fixture_end_to_end() {
    for engine in ["native", "batch"] {
        let out = Command::new(env!("CARGO_BIN_EXE_smalltrack"))
            .args(["track", "--input"])
            .arg(fixture_dir().join("tiny.det.txt"))
            .args(["--format", "auto", "--gt"])
            .arg(fixture_dir().join("tiny.gt.txt"))
            .args(["--engine", engine])
            .output()
            .expect("spawn track --input");
        let stdout = String::from_utf8_lossy(&out.stdout);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(out.status.success(), "[{engine}] {stderr}");
        assert!(stdout.contains("CLEAR-MOT"), "[{engine}] {stdout}");
        assert!(stdout.contains("\"frames\": 60"), "[{engine}] {stdout}");
        assert!(stdout.contains("\"mota\":"), "[{engine}] {stdout}");
        assert!(stderr.contains("mot (high confidence"), "[{engine}] {stderr}");
        assert!(stderr.contains("0 errors, 0 warnings"), "[{engine}] {stderr}");
    }
    // junk input exits non-zero with the typed error, no panic
    let out = Command::new(env!("CARGO_BIN_EXE_smalltrack"))
        .args(["track", "--input"])
        .arg(fixture_dir().join("garbage.txt"))
        .output()
        .expect("spawn track --input garbage");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("cannot auto-detect format"), "{stderr}");
}

#[test]
fn convert_cli_round_trips_the_fixtures_byte_exactly() {
    let dir = std::env::temp_dir().join(format!("smalltrack_convert_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // det -> coco -> det through the CLI reproduces both fixtures
    let steps = [
        ("tiny.det.txt", "coco", "out.coco.json", "tiny.coco.json"),
        ("tiny.coco.json", "mot", "out.det.txt", "tiny.det.txt"),
        ("tiny.gt.txt", "mot-gt", "out.gt.txt", "tiny.gt.txt"),
    ];
    for (input, to, out_name, want) in steps {
        let out_path = dir.join(out_name);
        let out = Command::new(env!("CARGO_BIN_EXE_smalltrack"))
            .args(["convert", "--input"])
            .arg(fixture_dir().join(input))
            .args(["--to", to, "--out"])
            .arg(&out_path)
            .output()
            .expect("spawn convert");
        assert!(out.status.success(), "{input} -> {to}: {}", String::from_utf8_lossy(&out.stderr));
        assert_eq!(
            std::fs::read_to_string(&out_path).unwrap(),
            fixture(want),
            "{input} -> {to} must reproduce {want} byte-for-byte"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
