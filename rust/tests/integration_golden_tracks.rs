//! End-to-end parity: the Rust SORT vs the original-style Python SORT.
//!
//! `make artifacts` runs `python/baseline/sort_python.py` (the faithful
//! abewley/sort reimplementation) on a deterministic mini-sequence and
//! dumps its per-frame output. The Rust tracker must produce the same
//! tracks — same ids, same boxes — frame by frame.

use smalltrack::data::json::parse_file;
use smalltrack::sort::{Bbox, Sort, SortParams};

#[test]
fn rust_sort_matches_python_baseline_tracks() {
    let path = smalltrack::runtime::artifacts_dir().join("golden_tracks.json");
    if !path.exists() {
        eprintln!("skipped: run `make artifacts` first");
        return;
    }
    let golden = parse_file(&path).unwrap();
    let params = golden.req("params");
    assert_eq!(params.req("max_age").num(), 1.0);
    assert_eq!(params.req("min_hits").num(), 3.0);

    let mut sort = Sort::new(SortParams::default());
    let frames = golden.req("frames").arr();
    let tracks = golden.req("tracks").arr();
    assert_eq!(frames.len(), tracks.len());

    for (k, (frame, want)) in frames.iter().zip(tracks.iter()).enumerate() {
        let boxes: Vec<Bbox> = frame
            .f64_mat()
            .iter()
            .map(|b| Bbox::new(b[0], b[1], b[2], b[3]))
            .collect();
        let mut got: Vec<_> = sort.update(&boxes).to_vec();
        got.sort_by_key(|t| t.id);
        let mut want_rows = want.f64_mat();
        want_rows.sort_by(|a, b| a[4].partial_cmp(&b[4]).unwrap());

        assert_eq!(got.len(), want_rows.len(), "frame {k}: track count");
        for (g, w) in got.iter().zip(&want_rows) {
            assert_eq!(g.id, w[4] as u64, "frame {k}: id");
            assert!((g.bbox.x1 - w[0]).abs() < 1e-6, "frame {k} id {} x1", g.id);
            assert!((g.bbox.y1 - w[1]).abs() < 1e-6, "frame {k}");
            assert!((g.bbox.x2 - w[2]).abs() < 1e-6, "frame {k}");
            assert!((g.bbox.y2 - w[3]).abs() < 1e-6, "frame {k}");
        }
    }
}
