#!/usr/bin/env python3
"""Check that every relative markdown link in the repo resolves.

Walks all *.md files (skipping .git/ and target/), extracts inline
links `[text](target)`, and verifies that each non-external target —
after stripping any `#fragment` — exists on disk relative to the file
that links it. External schemes (http/https/mailto) and pure
in-page anchors (`#section`) are skipped; anchor *presence* in the
target file is not checked, only that the file itself exists.

Exit 1 with one line per broken link; exit 0 silently when clean.
Run directly (`python3 tools/check_md_links.py`) or via
`make check-links`; CI's docs job runs it on every push.
"""

import os
import re
import sys

SKIP_DIRS = {".git", "target", "node_modules", "__pycache__"}
# inline links only; reference-style links are not used in this repo
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:")


def md_files(root):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in filenames:
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def strip_code(text):
    """Drop fenced and inline code spans — links in there are examples."""
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return re.sub(r"`[^`]*`", "", text)


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    broken = []
    checked = 0
    for path in sorted(md_files(root)):
        with open(path, encoding="utf-8") as fh:
            text = strip_code(fh.read())
        for target in LINK_RE.findall(text):
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            checked += 1
            resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                broken.append(
                    f"{os.path.relpath(path, root)}: broken link -> {target}"
                )
    if broken:
        print("\n".join(broken), file=sys.stderr)
        print(f"{len(broken)} broken markdown link(s)", file=sys.stderr)
        return 1
    print(f"ok: {checked} relative markdown links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
