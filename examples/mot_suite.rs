//! End-to-end driver (EXPERIMENTS.md §E2E): the full Table I workload
//! through every layer of the system.
//!
//! ```bash
//! make artifacts && cargo run --release --example mot_suite
//! ```
//!
//! 1. generates the 11-sequence synthetic MOT-2015 suite (Table I
//!    properties), writes real `det.txt` files;
//! 2. tracks every sequence with the native engine, reporting the
//!    paper's per-sequence FPS and the Table I columns;
//! 3. cross-checks one sequence on the AOT/XLA tracker-bank path
//!    (L3→L2→L1 composition) — skipped with a warning if `make
//!    artifacts` hasn't run;
//! 4. serves all 11 sequences as paced online streams and reports
//!    latency percentiles;
//! 5. prints the aggregate single-core FPS (the paper's headline
//!    number for this machine).

use smalltrack::coordinator::policy::run_sequence_serial;
use smalltrack::coordinator::{serve, Pacing, ServerConfig, VideoStream};
use smalltrack::data::mot::write_det_file;
use smalltrack::data::synth::{generate_suite, MOT15_PROPERTIES};
use smalltrack::engine::{EngineKind, TrackerEngine};
use smalltrack::sort::{Bbox, SortParams};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let params = SortParams { timing: false, ..Default::default() };
    let out_dir = std::env::temp_dir().join("smalltrack_mot_suite");

    // --- 1. dataset (Table I)
    let suite = generate_suite(7);
    println!("=== Table I: dataset properties (synthetic MOT-2015) ===");
    println!("{:<16} {:>7} {:>17}", "Dataset", "#Frames", "Max Tracked Object");
    for (s, &(_, frames, max_obj)) in suite.iter().zip(&MOT15_PROPERTIES) {
        assert_eq!(s.sequence.n_frames() as u32, frames);
        println!("{:<16} {:>7} {:>17}", s.sequence.name, frames, max_obj);
        write_det_file(&s.sequence, &out_dir.join(&s.sequence.name).join("det/det.txt"))?;
    }
    println!("det.txt files under {}\n", out_dir.display());

    // --- 2. native tracking, per-sequence FPS
    println!("=== Native single-core tracking ===");
    let mut total_frames = 0u64;
    let mut total_secs = 0.0;
    let mut total_tracks = 0u64;
    for s in &suite {
        let t0 = Instant::now();
        let (frames, tracks) = run_sequence_serial(s, params);
        let dt = t0.elapsed().as_secs_f64();
        total_frames += frames;
        total_secs += dt;
        total_tracks += tracks;
        println!(
            "{:<16} {:>6} frames  {:>8.0} fps  {:>6} track-frames",
            s.sequence.name,
            frames,
            frames as f64 / dt,
            tracks
        );
    }
    println!(
        "TOTAL {total_frames} frames  {:.3}s  {:.0} FPS single-core  ({total_tracks} track-frames)\n",
        total_secs,
        total_frames as f64 / total_secs
    );

    // --- 3. tracker-bank cross-check (three-layer composition) —
    // engines injected through the trait, as the coordinator does
    println!("=== tracker-bank cross-check (PETS09-S2L1, first 200 frames) ===");
    let mut bank = EngineKind::Xla.build(params)?;
    let mut native = EngineKind::Native.build(params)?;
    let mut agree = true;
    let mut boxes: Vec<Bbox> = Vec::new();
    for frame in suite[0].sequence.frames.iter().take(200) {
        boxes.clear();
        boxes.extend(frame.detections.iter().map(|d| d.bbox));
        let mut a: Vec<u64> = native.update(&boxes).iter().map(|t| t.id).collect();
        let mut b: Vec<u64> = bank.update(&boxes).iter().map(|t| t.id).collect();
        a.sort_unstable();
        b.sort_unstable();
        if a != b {
            agree = false;
            println!("  frame {}: native {a:?} vs xla {b:?}", frame.index);
        }
    }
    println!(
        "  native and tracker-bank paths {} over 200 frames\n",
        if agree { "AGREE" } else { "DISAGREE" }
    );
    assert!(agree, "three-layer composition broken");

    // --- 4. online serving
    println!("=== Online serving: 11 streams @ 30fps, 2 workers ===");
    let streams: Vec<VideoStream> = generate_suite(7)
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            let mut seq = s.sequence;
            seq.frames.truncate(90); // 3 seconds of "video" per stream
            VideoStream::new(i, seq, Pacing::fps(30.0))
        })
        .collect();
    let report = serve(streams, ServerConfig { workers: 2, ..Default::default() });
    let (p50, p95, p99, max) = report.latency.summary();
    println!(
        "  frames={} dropped={} wall={:.1}s",
        report.frames_done,
        report.dropped,
        report.elapsed.as_secs_f64()
    );
    println!("  latency p50={p50:?} p95={p95:?} p99={p99:?} max={max:?}");
    println!("\nmot_suite end-to-end driver: OK");
    Ok(())
}
