//! The batched SoA engine in one sitting: byte-identical tracks to the
//! native engine, fewer counter events, and a quick steady-state
//! latency comparison.
//!
//! ```bash
//! cargo run --release --example batch_engine
//! ```
//!
//! The `batch` backend keeps every live tracker's Kalman state in
//! structure-of-arrays lanes and runs predict/update as fused loops —
//! the paper's "batch tiny independent updates into one invocation"
//! idea applied to our own CPU hot path. Because it performs the exact
//! same scalar operation sequence per tracker, its output is identical
//! to `--engine native` down to the last bit, which this example
//! asserts before it times anything.

use smalltrack::data::synth::{generate_sequence, SynthConfig};
use smalltrack::engine::{run_sequence, EngineKind, TrackerEngine};
use smalltrack::linalg::{reset_counters, snapshot};
use smalltrack::sort::{Bbox, SortParams};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let params = SortParams { timing: false, ..Default::default() };
    let synth = generate_sequence(&SynthConfig::mot15("BATCH-demo", 400, 10, 11));

    // --- 1. byte-identical output, frame by frame
    let mut native = EngineKind::Native.build(params)?;
    let mut batch = EngineKind::Batch.build(params)?;
    let mut boxes: Vec<Bbox> = Vec::new();
    for frame in &synth.sequence.frames {
        boxes.clear();
        boxes.extend(frame.detections.iter().map(|d| d.bbox));
        let a = native.update(&boxes).to_vec();
        let b = batch.update(&boxes);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.id, y.id);
            assert_eq!(
                x.bbox.to_array().map(f64::to_bits),
                y.bbox.to_array().map(f64::to_bits),
                "engines diverged at frame {}",
                frame.index
            );
        }
    }
    println!("native and batch tracks are byte-identical over 400 frames");

    // --- 2. counter events: per tracker vs per frame
    for kind in [EngineKind::Native, EngineKind::Batch] {
        let mut engine = kind.build(params)?;
        reset_counters();
        run_sequence(&mut *engine, &synth.sequence);
        let total = snapshot().total();
        println!(
            "{:<7} {:>8} kernel-counter events, {:>12} flops accounted",
            kind.label(),
            total.calls,
            total.flops
        );
    }
    println!("(same flops, far fewer events: batch records once per frame)");

    // --- 3. steady-state latency, one warm engine per backend
    for kind in [EngineKind::Native, EngineKind::Batch] {
        let mut engine = kind.build(params)?;
        run_sequence(&mut *engine, &synth.sequence); // warm-up
        let reps = 20;
        let t0 = Instant::now();
        for _ in 0..reps {
            engine.reset();
            run_sequence(&mut *engine, &synth.sequence);
        }
        let dt = t0.elapsed().as_secs_f64();
        let frames = synth.sequence.n_frames() as f64 * reps as f64;
        println!(
            "{:<7} {:>8.2} us/frame  ({:.0} fps single stream)",
            kind.label(),
            dt / frames * 1e6,
            frames / dt
        );
    }
    println!("\nbatch_engine example: OK");
    Ok(())
}
