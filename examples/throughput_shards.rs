//! Throughput shards: 64 heterogeneous streams saturating the
//! work-stealing scheduler.
//!
//! ```bash
//! cargo run --release --example throughput_shards
//! ```
//!
//! The paper's throughput result assumes every core gets the same
//! amount of video. Real fleets don't: this example builds 64 synthetic
//! streams whose lengths span 40–740 frames (an 18× spread), shards
//! them across workers, and compares:
//!
//! * **pinned** — streams stay on their home shard (`id % workers`),
//!   the paper's static partition: the worker that drew the long
//!   streams finishes last while the others idle;
//! * **stealing** — idle workers steal the oldest queued stream, so
//!   the ragged tail is reclaimed.
//!
//! It also demonstrates admission control: with a tiny `Block` ingress
//! the submitter is backpressured (lossless), while `DropOldest`
//! sheds the longest-waiting streams and counts them.

use smalltrack::coordinator::backpressure::PushPolicy;
use smalltrack::coordinator::scheduler::{
    run_shards, Scheduler, SchedulerConfig, ShardPolicy,
};
use smalltrack::data::synth::{generate_sequence, SynthConfig, SynthSequence};
use std::sync::Arc;

/// 64 streams with a deliberately lumpy length distribution: mostly
/// short clips plus a handful of long surveillance-style feeds.
fn hetero_fleet() -> Vec<SynthSequence> {
    (0..64)
        .map(|i| {
            let frames = match i % 8 {
                0 => 740, // long feed: the shard-imbalance driver
                1..=3 => 190,
                _ => 40, // short clips
            };
            let objects = 3 + (i % 5) as u32;
            generate_sequence(&SynthConfig::mot15(&format!("CAM-{i:02}"), frames, objects, i))
        })
        .collect()
}

fn main() {
    let fleet = hetero_fleet();
    let total_frames: u64 = fleet.iter().map(|s| s.sequence.n_frames() as u64).sum();
    println!(
        "fleet: {} streams, {} frames (lengths 40..740 — an 18x spread)\n",
        fleet.len(),
        total_frames
    );

    println!("=== pinned vs stealing across worker counts ===");
    println!(
        "{:>8} {:>12} {:>12} {:>8} {:>10}",
        "workers", "pinned FPS", "steal FPS", "stolen", "steal/pin"
    );
    for workers in [1usize, 2, 4, 8] {
        let mut fps = [0.0f64; 2];
        let mut stolen = 0;
        for (i, policy) in [ShardPolicy::Pinned, ShardPolicy::Stealing].iter().enumerate() {
            let r = run_shards(
                &fleet,
                SchedulerConfig {
                    workers,
                    shard_policy: *policy,
                    queue_capacity: 128,
                    ..Default::default()
                },
            );
            assert_eq!(r.streams, 64);
            assert_eq!(r.frames, total_frames);
            fps[i] = r.fps();
            stolen = r.stolen;
        }
        println!(
            "{workers:>8} {:>12.0} {:>12.0} {stolen:>8} {:>9.2}x",
            fps[0],
            fps[1],
            fps[1] / fps[0]
        );
    }

    println!("\n=== per-worker view (4 workers, stealing) ===");
    let r = run_shards(
        &fleet,
        SchedulerConfig {
            workers: 4,
            shard_policy: ShardPolicy::Stealing,
            queue_capacity: 128,
            ..Default::default()
        },
    );
    for (w, c) in r.per_worker.iter().enumerate() {
        println!(
            "worker {w}: streams={:>2} stolen={:>2} frames={:>5} busy_fps={:>8.0}",
            c.streams,
            c.stolen,
            c.frames,
            c.fps.fps()
        );
    }
    let (p50, p95, p99, max) = r.latency.summary();
    println!("per-frame engine latency: p50={p50:?} p95={p95:?} p99={p99:?} max={max:?}");

    println!("\n=== admission control (1 worker, 2-deep ingress, 2 in flight) ===");
    for policy in [PushPolicy::Block, PushPolicy::DropOldest] {
        let sched = Scheduler::new(SchedulerConfig {
            workers: 1,
            queue_capacity: 2,
            max_in_flight: 2,
            admission: policy,
            ..Default::default()
        });
        for s in &fleet {
            // Block: this call stalls until the worker frees capacity
            // (lossless). DropOldest: it returns immediately and the
            // longest-waiting undispatched stream is shed instead.
            sched.submit(Arc::new(s.sequence.clone()));
        }
        let r = sched.join();
        println!(
            "{:?}: ran {} streams, shed {} (submitted 64)",
            policy,
            r.streams,
            r.shed
        );
        assert_eq!(r.streams + r.shed, 64, "every stream is run or counted shed");
    }
}
