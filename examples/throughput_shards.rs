//! Throughput shards: 64 heterogeneous streams saturating the
//! work-stealing scheduler.
//!
//! ```bash
//! cargo run --release --example throughput_shards
//! ```
//!
//! The paper's throughput result assumes every core gets the same
//! amount of video. Real fleets don't: this example builds 64 synthetic
//! streams whose lengths span 40–740 frames (an 18× spread), shards
//! them across workers, and compares:
//!
//! * **pinned** — streams stay on their home shard (`id % workers`),
//!   the paper's static partition: the worker that drew the long
//!   streams finishes last while the others idle;
//! * **stealing** — idle workers steal the oldest queued stream, so
//!   the ragged tail is reclaimed.
//!
//! It also demonstrates admission control: with a tiny `Block` ingress
//! the submitter is backpressured (lossless), while `DropOldest`
//! sheds the longest-waiting streams and counts them.
//!
//! Finally, the same 64-stream fleet is replayed through the live
//! session API (`TrackingService`): sessions admitted at runtime,
//! frames pushed incrementally, metrics observable mid-flight — and
//! the exact same total track output as the batch scheduler.

use smalltrack::coordinator::backpressure::PushPolicy;
use smalltrack::coordinator::scheduler::{
    run_shards, Scheduler, SchedulerConfig, ShardPolicy,
};
use smalltrack::coordinator::service::{ServiceConfig, SessionParams, TrackingService};
use smalltrack::data::synth::{generate_sequence, SynthConfig, SynthSequence};
use smalltrack::sort::Bbox;
use std::sync::Arc;

/// 64 streams with a deliberately lumpy length distribution: mostly
/// short clips plus a handful of long surveillance-style feeds.
fn hetero_fleet() -> Vec<SynthSequence> {
    (0..64)
        .map(|i| {
            let frames = match i % 8 {
                0 => 740, // long feed: the shard-imbalance driver
                1..=3 => 190,
                _ => 40, // short clips
            };
            let objects = 3 + (i % 5) as u32;
            generate_sequence(&SynthConfig::mot15(&format!("CAM-{i:02}"), frames, objects, i))
        })
        .collect()
}

fn main() {
    let fleet = hetero_fleet();
    let total_frames: u64 = fleet.iter().map(|s| s.sequence.n_frames() as u64).sum();
    println!(
        "fleet: {} streams, {} frames (lengths 40..740 — an 18x spread)\n",
        fleet.len(),
        total_frames
    );

    println!("=== pinned vs stealing across worker counts ===");
    println!(
        "{:>8} {:>12} {:>12} {:>8} {:>10}",
        "workers", "pinned FPS", "steal FPS", "stolen", "steal/pin"
    );
    for workers in [1usize, 2, 4, 8] {
        let mut fps = [0.0f64; 2];
        let mut stolen = 0;
        for (i, policy) in [ShardPolicy::Pinned, ShardPolicy::Stealing].iter().enumerate() {
            let r = run_shards(
                &fleet,
                SchedulerConfig {
                    workers,
                    shard_policy: *policy,
                    queue_capacity: 128,
                    ..Default::default()
                },
            );
            assert_eq!(r.streams, 64);
            assert_eq!(r.frames, total_frames);
            fps[i] = r.fps();
            stolen = r.stolen;
        }
        println!(
            "{workers:>8} {:>12.0} {:>12.0} {stolen:>8} {:>9.2}x",
            fps[0],
            fps[1],
            fps[1] / fps[0]
        );
    }

    println!("\n=== per-worker view (4 workers, stealing) ===");
    let r = run_shards(
        &fleet,
        SchedulerConfig {
            workers: 4,
            shard_policy: ShardPolicy::Stealing,
            queue_capacity: 128,
            ..Default::default()
        },
    );
    for (w, c) in r.per_worker.iter().enumerate() {
        println!(
            "worker {w}: streams={:>2} stolen={:>2} frames={:>5} busy_fps={:>8.0}",
            c.streams,
            c.stolen,
            c.frames,
            c.fps.fps()
        );
    }
    let (p50, p95, p99, max) = r.latency.summary();
    println!("per-frame engine latency: p50={p50:?} p95={p95:?} p99={p99:?} max={max:?}");

    println!("\n=== admission control (1 worker, 2-deep ingress, 2 in flight) ===");
    for policy in [PushPolicy::Block, PushPolicy::DropOldest] {
        let sched = Scheduler::new(SchedulerConfig {
            workers: 1,
            queue_capacity: 2,
            max_in_flight: 2,
            admission: policy,
            ..Default::default()
        });
        for s in &fleet {
            // Block: this call stalls until the worker frees capacity
            // (lossless). DropOldest: it returns immediately and the
            // longest-waiting undispatched stream is shed instead.
            sched.submit(Arc::new(s.sequence.clone()));
        }
        let r = sched.join();
        println!(
            "{:?}: ran {} streams, shed {} (submitted 64)",
            policy,
            r.streams,
            r.shed
        );
        assert_eq!(r.streams + r.shed, 64, "every stream is run or counted shed");
    }

    println!("\n=== the same fleet through the live session API (4 workers) ===");
    // batch anchor: the scheduler's total track output on this fleet
    let anchor = run_shards(
        &fleet,
        SchedulerConfig { workers: 4, queue_capacity: 128, ..Default::default() },
    )
    .tracks_out;
    let svc = TrackingService::start(ServiceConfig {
        workers: 4,
        push_policy: PushPolicy::Block, // lossless, like the scheduler
        ..Default::default()
    })
    .expect("start service");
    // sessions admitted one by one at runtime; frames fed round-robin
    // so every worker stays busy despite the 18x length spread
    let mut feeds: Vec<(&SynthSequence, _, usize)> = fleet
        .iter()
        .map(|s| (s, svc.open_session(SessionParams::default()).expect("open"), 0usize))
        .collect();
    let mut handles = Vec::with_capacity(feeds.len());
    let mut live_printed = false;
    while !feeds.is_empty() {
        let mut i = 0;
        while i < feeds.len() {
            let (s, h, cursor) = &mut feeds[i];
            let end = (*cursor + 8).min(s.sequence.frames.len());
            for frame in &s.sequence.frames[*cursor..end] {
                let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
                h.push_frame(boxes);
            }
            *cursor = end;
            if *cursor == s.sequence.frames.len() {
                h.close();
                let (_, h, _) = feeds.swap_remove(i);
                handles.push(h);
            } else {
                i += 1;
            }
        }
        if !live_printed && handles.len() >= 32 && !feeds.is_empty() {
            live_printed = true;
            let m = svc.metrics();
            println!(
                "  live @ {} sessions retired: open={} queued={} frames_done={}",
                handles.len(),
                m.open_sessions,
                m.queue_depth(),
                m.frames_done
            );
        }
    }
    let mut tracks = 0u64;
    for h in &handles {
        tracks += h.join().tracks_out;
    }
    let m = svc.shutdown();
    println!(
        "  sessions={} frames={} tracks={} busy_fps={:.0}",
        m.sessions_closed,
        m.frames_done,
        tracks,
        m.aggregate_fps().fps()
    );
    assert_eq!(m.frames_done, total_frames);
    assert_eq!(tracks, anchor, "session path diverged from the batch scheduler");
}
