//! Scaling laboratory: the paper's §VI experiment, live.
//!
//! ```bash
//! cargo run --release --example scaling_lab
//! ```
//!
//! Part A runs the three policies for real at small thread counts on
//! this machine (oversubscribed on a 1-core box — which *demonstrates*
//! the strong-scaling overhead rather than hiding it).
//! Part B calibrates the discrete-event simulator from the measured
//! single-core service times and regenerates the paper's Table VI at
//! 1/18/36/72 cores on the SKX-6140 profile.

use smalltrack::coordinator::policy::{run_policy, ScalingPolicy};
use smalltrack::data::synth::generate_suite;
use smalltrack::simcore::{calibrate_workload, simulate, MachineProfile, SimPolicy};
use smalltrack::sort::SortParams;

fn main() {
    let suite = generate_suite(7);
    let params = SortParams { timing: false, ..Default::default() };

    println!("=== Part A: measured on this machine ({} hw threads) ===", {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    });
    println!("{:<22} {:>8} {:>10}", "policy", "wall(s)", "FPS");
    for p in [1usize, 2, 4] {
        for policy in [
            ScalingPolicy::Strong { threads: p },
            ScalingPolicy::Weak { workers: p },
            ScalingPolicy::Throughput { workers: p },
        ] {
            let o = run_policy(&suite, policy, params);
            println!("{:<22} {:>8.3} {:>10.0}", o.policy.label(), o.elapsed.as_secs_f64(), o.fps());
        }
    }

    println!("\n=== Part B: calibrated simulation, SKX-6140 profile (Table VI) ===");
    let w = calibrate_workload(&suite, 3);
    println!(
        "calibration anchor: single-core {:.0} FPS over {} frames",
        w.single_core_fps(),
        w.total_frames()
    );
    println!("{:>6} {:>10} {:>10} {:>12}", "Cores", "Strong", "Weak", "Throughput");
    let m = MachineProfile::skx6140();
    for p in [1usize, 18, 36, 72] {
        let s = simulate(&w, &m, SimPolicy::Strong { threads: p }).fps_paper_metric;
        let wk = simulate(&w, &m, SimPolicy::Weak { cores: p }).fps_paper_metric;
        let tp = simulate(&w, &m, SimPolicy::Throughput { cores: p }).fps_paper_metric;
        println!("{p:>6} {s:>10.0} {wk:>10.0} {tp:>12.0}");
    }
    println!("\npaper's Table VI shape: strong degrades with p; weak/throughput sustain");
}
