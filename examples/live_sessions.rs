//! Live sessions: cameras attaching and detaching on a long-lived
//! `TrackingService`.
//!
//! ```bash
//! cargo run --release --example live_sessions
//! ```
//!
//! The batch `serve()` front door needs every stream up front and
//! blocks until all of them drain. Real deployments don't work that
//! way: feeds come and go while the service stays up. This example
//! drives exactly that shape:
//!
//! 1. a first wave of cameras opens — with *mixed engines* on one
//!    service (`native`, `batch`, `strong:2`) and ragged lengths;
//! 2. mid-run, while wave 1 is still streaming, a second wave attaches
//!    (runtime admission — no restart, no rebuild);
//! 3. short sessions close early and their workers' warm engines are
//!    reused by later sessions with the same parameters;
//! 4. `service.metrics()` snapshots the fleet live at each phase;
//! 5. every session's tracks are checked against a fresh serial run of
//!    the same engine — identical, no matter what else was in flight.

use smalltrack::coordinator::service::{
    ServiceConfig, SessionHandle, SessionParams, TrackingService,
};
use smalltrack::data::mot::Sequence;
use smalltrack::data::synth::{generate_sequence, SynthConfig};
use smalltrack::engine::EngineKind;
use smalltrack::sort::Bbox;

/// A camera feed: a stored sequence plus the engine its session asks for.
struct Camera {
    name: String,
    seq: Sequence,
    engine: EngineKind,
}

fn fleet(wave: u32, count: usize, base_seed: u64) -> Vec<Camera> {
    let engines = [EngineKind::Native, EngineKind::Batch, EngineKind::Strong { threads: 2 }];
    (0..count)
        .map(|i| {
            let frames = 40 + 60 * (i as u32 % 3); // ragged: 40/100/160
            let name = format!("w{wave}-cam{i}");
            Camera {
                seq: generate_sequence(&SynthConfig::mot15(
                    &name,
                    frames,
                    3 + (i as u32 % 4),
                    base_seed + i as u64,
                ))
                .sequence,
                name,
                engine: engines[i % engines.len()],
            }
        })
        .collect()
}

/// Serial reference: the same engine, a fresh instance, frames
/// numbered by position — what the session output must equal.
fn serial_rows(cam: &Camera) -> Vec<(u32, u64, Bbox)> {
    let mut engine = cam.engine.build(SessionParams::default().sort_params).unwrap();
    let mut rows = Vec::new();
    for (i, frame) in cam.seq.frames.iter().enumerate() {
        let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
        for t in engine.update(&boxes) {
            rows.push((i as u32 + 1, t.id, t.bbox));
        }
    }
    rows
}

fn open(svc: &TrackingService, cam: &Camera) -> SessionHandle {
    let h = svc
        .open_session(SessionParams { engine: cam.engine, ..Default::default() })
        .expect("open session");
    println!(
        "  + {} ({} frames, {} engine) -> worker {}",
        cam.name,
        cam.seq.frames.len(),
        cam.engine.spec(),
        h.worker()
    );
    h
}

/// Push up to `n` frames from the camera's cursor; returns frames pushed.
fn push_some(cam: &Camera, h: &SessionHandle, cursor: &mut usize, n: usize) -> usize {
    let end = (*cursor + n).min(cam.seq.frames.len());
    for frame in &cam.seq.frames[*cursor..end] {
        let boxes: Vec<Bbox> = frame.detections.iter().map(|d| d.bbox).collect();
        h.push_frame(boxes);
    }
    let pushed = end - *cursor;
    *cursor = end;
    pushed
}

fn print_metrics(svc: &TrackingService, label: &str) {
    let m = svc.metrics();
    println!(
        "  [{label}] sessions open={} closed={} frames={} queued={} busy_fps={:.0}",
        m.open_sessions,
        m.sessions_closed,
        m.frames_done,
        m.queue_depth(),
        m.aggregate_fps().fps()
    );
    for (w, snap) in m.per_worker.iter().enumerate() {
        println!(
            "      worker {w}: open={} frames={} busy_fps={:.0}",
            snap.open_sessions, snap.frames_done, snap.fps.fps()
        );
    }
}

fn main() {
    // Block = lossless ingestion: the verification below demands that
    // every frame reaches its engine (DropOldest would shed under the
    // burst pushes and legitimately change the output)
    let svc = TrackingService::start(ServiceConfig {
        workers: 3,
        push_policy: smalltrack::coordinator::PushPolicy::Block,
        ..Default::default()
    })
    .expect("start service");

    println!("=== wave 1 attaches (mixed engines, ragged lengths) ===");
    let wave1 = fleet(1, 5, 100);
    let mut live: Vec<(Camera, SessionHandle, usize)> =
        wave1.into_iter().map(|c| { let h = open(&svc, &c); (c, h, 0) }).collect();

    // stream roughly half of wave 1
    for (cam, h, cursor) in &mut live {
        let half = cam.seq.frames.len() / 2;
        push_some(cam, h, cursor, half);
    }
    print_metrics(&svc, "wave 1 mid-stream");

    println!("\n=== wave 2 attaches while wave 1 is mid-stream ===");
    let wave2 = fleet(2, 4, 200);
    for cam in wave2 {
        let h = open(&svc, &cam);
        live.push((cam, h, 0));
    }
    print_metrics(&svc, "both waves live");

    // interleave the rest: push in small slices, closing as feeds end —
    // sessions retire at different times, exactly like real detaches
    println!("\n=== streaming to completion (sessions detach as feeds end) ===");
    let mut finished: Vec<(Camera, SessionHandle)> = Vec::new();
    while !live.is_empty() {
        let mut i = 0;
        while i < live.len() {
            let (cam, h, cursor) = &mut live[i];
            push_some(cam, h, cursor, 16);
            if *cursor == cam.seq.frames.len() {
                h.close();
                let (cam, h, _) = live.swap_remove(i);
                finished.push((cam, h));
            } else {
                i += 1;
            }
        }
    }
    print_metrics(&svc, "all feeds closed, draining");

    // verify: every session's tracks equal a fresh serial run of the
    // same engine — runtime admission changed nothing about the math
    println!("\n=== verification vs serial runs ===");
    let mut total_rows = 0usize;
    for (cam, h) in &finished {
        let stats = h.join();
        let rows = h.poll_tracks();
        assert_eq!(stats.dropped, 0, "{}: Block ingestion must be lossless", cam.name);
        assert_eq!(
            rows,
            serial_rows(cam),
            "{}: session tracks diverged from a serial {} run",
            cam.name,
            cam.engine.spec()
        );
        total_rows += rows.len();
    }
    println!(
        "  {} sessions x byte-identical tracks ({} track-frames total)",
        finished.len(),
        total_rows
    );

    let m = svc.shutdown();
    println!(
        "\nfinal: {} sessions served, {} frames, {} track-frames, busy_fps={:.0}",
        m.sessions_closed,
        m.frames_done,
        m.tracks_out,
        m.aggregate_fps().fps()
    );
    assert_eq!(m.sessions_closed, 9);
    assert_eq!(m.open_sessions, 0);
}
