//! Online stream-serving scenarios (E10): the latency side of the
//! paper's "latency-sensitive" claim.
//!
//! ```bash
//! cargo run --release --example stream_server
//! ```
//!
//! Three scenarios over the synthetic suite:
//!   1. steady state — 11 camera streams at 30 fps, 2 workers;
//!   2. burst — the same streams replayed unpaced (worst-case arrival);
//!   3. overload — 22 streams into 1 worker with a shallow queue,
//!      demonstrating bounded-staleness shedding (DropOldest) instead
//!      of unbounded latency.

use smalltrack::coordinator::backpressure::PushPolicy;
use smalltrack::coordinator::{serve, Pacing, ServerConfig, VideoStream};
use smalltrack::data::synth::{generate_sequence, SynthConfig};

fn streams(n: usize, frames: u32, pacing: Pacing) -> Vec<VideoStream> {
    (0..n)
        .map(|i| {
            let synth = generate_sequence(&SynthConfig::mot15(
                &format!("cam{i:02}"),
                frames,
                3 + (i as u32 % 9),
                1000 + i as u64,
            ));
            VideoStream::new(i, synth.sequence, pacing)
        })
        .collect()
}

fn report(name: &str, r: &smalltrack::coordinator::ServerReport) {
    let (p50, p95, p99, max) = r.latency.summary();
    println!("--- {name} ---");
    println!(
        "  frames={} dropped={} wall={:.2}s agg_fps={:.0}",
        r.frames_done,
        r.dropped,
        r.elapsed.as_secs_f64(),
        r.fps()
    );
    println!("  latency: p50={p50:?}  p95={p95:?}  p99={p99:?}  max={max:?}");
    for (w, fps) in r.per_worker_fps.iter().enumerate() {
        println!("  worker {w}: {} frames, busy-fps {:.0}", fps.frames(), fps.fps());
    }
}

fn main() {
    println!("scenario 1: steady state — 11 streams @ 30fps, 2 workers");
    let r = serve(
        streams(11, 150, Pacing::fps(30.0)),
        ServerConfig { workers: 2, ..Default::default() },
    );
    report("steady", &r);
    assert_eq!(r.dropped, 0, "steady state must not shed");

    println!("\nscenario 2: burst replay — same load, unpaced, lossless queueing");
    let r = serve(
        streams(11, 150, Pacing::Unpaced),
        ServerConfig { workers: 2, push_policy: PushPolicy::Block, ..Default::default() },
    );
    report("burst", &r);

    println!("\nscenario 3: overload — 22 streams, 1 worker, queue depth 8, shedding");
    let r = serve(
        streams(22, 100, Pacing::Unpaced),
        ServerConfig { workers: 1, queue_capacity: 8, ..Default::default() },
    );
    report("overload", &r);
    println!(
        "  (dropped {} frames — bounded staleness instead of unbounded latency)",
        r.dropped
    );
}
