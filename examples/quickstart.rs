//! Quickstart: track a synthetic pedestrian sequence in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a 120-frame sequence with up to 6 objects (MOT-2015-like
//! detector noise), runs SORT, and prints the confirmed tracks of the
//! final frames plus the per-phase time breakdown the paper profiles.

use smalltrack::data::synth::{generate_sequence, SynthConfig};
use smalltrack::sort::{Bbox, Phase, Sort, SortParams};

fn main() {
    // 1. a synthetic "video": detections per frame in MOT det.txt shape
    let synth = generate_sequence(&SynthConfig::mot15("quickstart", 120, 6, 42));

    // 2. the tracker (defaults = the original SORT's parameters)
    let mut tracker = Sort::new(SortParams::default());

    // 3. feed frames in order; update() must run every frame
    let mut boxes: Vec<Bbox> = Vec::new();
    for frame in &synth.sequence.frames {
        boxes.clear();
        boxes.extend(frame.detections.iter().map(|d| d.bbox));
        let tracks = tracker.update(&boxes);
        if frame.index >= 115 {
            println!("frame {:>3}:", frame.index);
            for t in tracks {
                println!(
                    "   track {:>2}  [{:7.1} {:7.1} {:7.1} {:7.1}]",
                    t.id, t.bbox.x1, t.bbox.y1, t.bbox.x2, t.bbox.y2
                );
            }
        }
    }

    // 4. the paper's per-phase profile (Table IV shape)
    println!("\nphase breakdown over {} frames:", tracker.frame_count());
    let pct = tracker.phases.percentages();
    for phase in Phase::ALL {
        let s = tracker.phases.get(phase);
        println!(
            "  {:<20} {:>5.1}%  ({} calls, AI {:.2} flops/byte)",
            phase.label(),
            pct[phase as usize],
            s.count,
            s.ai_ws()
        );
    }
}
