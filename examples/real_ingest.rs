//! Real-input ingest: auto-detect a checked-in MOT fixture, validate
//! it, track it on the native and batch engines, and prove the two
//! produce bit-identical tracks — then score against ground truth.
//!
//! ```bash
//! cargo run --release --example real_ingest
//! ```
//!
//! This is the `track --input` CLI path as a library walkthrough: the
//! typed interchange IR (`data::ingest`) is how real MOT Challenge /
//! COCO files reach the engines, so the same fixture can be fed to any
//! `TrackerEngine` and scored with CLEAR-MOT against its gt file.

use smalltrack::data::ingest::{self, ParseMode, SourceFormat};
use smalltrack::engine::EngineKind;
use smalltrack::sort::{Bbox, SortParams};
use std::path::Path;

fn main() -> smalltrack::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/ingest");

    // 1. auto-detect the format from content (never the extension)
    let det_path = dir.join("tiny.det.txt");
    let (ir, guess) = ingest::load_path(&det_path, None, ParseMode::Strict)?;
    println!(
        "{}: detected {} ({} confidence: {})",
        det_path.display(),
        guess.format.label(),
        guess.confidence.label(),
        guess.detail
    );
    println!("  {} frames, {} detections", ir.n_frames(), ir.n_entries());

    // 2. collected typed validation — every finding, not just the first
    let report = ingest::validate(&ir);
    println!("  validation: {}", report.summary());
    for issue in &report.issues {
        println!("    {issue}");
    }

    // 3. the same real file through two engines
    let seq = ir.to_sequence();
    let mut outputs: Vec<Vec<(u32, u64, Bbox)>> = Vec::new();
    for kind in [EngineKind::Native, EngineKind::Batch] {
        let mut engine = kind.build(SortParams { timing: false, ..Default::default() })?;
        let mut rows = Vec::new();
        let mut boxes = Vec::new();
        for frame in &seq.frames {
            boxes.clear();
            boxes.extend(frame.detections.iter().map(|d| d.bbox));
            for t in engine.update(&boxes) {
                rows.push((frame.index, t.id, t.bbox));
            }
        }
        println!("  {}: {} track rows", kind.spec(), rows.len());
        outputs.push(rows);
    }

    // 4. batch is bit-identical to native — same ids, same box bits
    let (native, batch) = (&outputs[0], &outputs[1]);
    assert_eq!(native.len(), batch.len(), "row counts diverged");
    for (a, b) in native.iter().zip(batch) {
        assert_eq!((a.0, a.1), (b.0, b.1), "track identity diverged");
        assert_eq!(a.2.x1.to_bits(), b.2.x1.to_bits(), "box bits diverged");
        assert_eq!(a.2.y1.to_bits(), b.2.y1.to_bits(), "box bits diverged");
        assert_eq!(a.2.x2.to_bits(), b.2.x2.to_bits(), "box bits diverged");
        assert_eq!(a.2.y2.to_bits(), b.2.y2.to_bits(), "box bits diverged");
    }
    println!("  native and batch tracks are bit-identical");

    // 5. CLEAR-MOT against the fixture's ground truth
    let (gt, _) =
        ingest::load_path(&dir.join("tiny.gt.txt"), Some(SourceFormat::MotGt), ParseMode::Strict)?;
    let m = ingest::score_tracks(&gt, native, 0.5);
    println!(
        "  CLEAR-MOT: MOTA {:.4} MOTP {:.4} precision {:.4} recall {:.4} (gt {} tp {} fp {} fn {} idsw {})",
        m.mota(),
        m.motp(),
        m.precision(),
        m.recall(),
        m.n_gt,
        m.tp,
        m.fp,
        m.fn_,
        m.id_switches
    );
    assert!(m.mota() > 0.2, "implausible fixture MOTA");
    Ok(())
}
