# Build-time helpers. The Rust crate itself needs only `cargo build`.

ARTIFACTS_DIR ?= artifacts

.PHONY: artifacts test test-nocounters bench fmt clippy

# Lower the JAX/Pallas tracker-bank graphs to HLO text + export the
# golden parity/track JSONs and the manifest (requires python with jax;
# see python/compile/aot.py). Without this, the Rust side runs the
# built-in reference interpreter and the checked-in golden JSONs.
artifacts:
	cd python && python -m compile.aot --outdir ../$(ARTIFACTS_DIR)

test:
	cargo build --release && cargo test -q

# counters-off configuration: record() compiles to a no-op
test-nocounters:
	cargo test -q --no-default-features

bench:
	cargo bench

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings
