# Build-time helpers. The Rust crate itself needs only `cargo build`.

ARTIFACTS_DIR ?= artifacts

.PHONY: artifacts test test-nocounters bench bench-lanes fmt clippy lab-smoke lab-baseline wire-smoke fleet-smoke ingest-smoke check-links

# Lower the JAX/Pallas tracker-bank graphs to HLO text + export the
# golden parity/track JSONs and the manifest (requires python with jax;
# see python/compile/aot.py). Without this, the Rust side runs the
# built-in reference interpreter and the checked-in golden JSONs.
artifacts:
	cd python && python -m compile.aot --outdir ../$(ARTIFACTS_DIR)

test:
	cargo build --release && cargo test -q

# counters-off configuration: record() compiles to a no-op
test-nocounters:
	cargo test -q --no-default-features

bench:
	cargo bench

# Lane-width x precision ablation (scalar/4-wide/8-wide, f64/f32) —
# the second table of batch_vs_native, which also gates every f64 lane
# width bitwise against the native engine before timing.
bench-lanes:
	cargo bench --bench batch_vs_native

# The CI perf path: smoke grid -> JSON -> gate vs the checked-in floor
# baseline (see README "Performance tracking").
lab-smoke:
	cargo run --release -- lab run --smoke --json bench_smoke.json
	cargo run --release -- lab gate artifacts/bench_baseline.json bench_smoke.json --margin 3.0

# The CI wire path: netload under the seeded aggressive fault schedule
# (exit 1 unless the frame ledger conserves and the delivered tracks
# are bit-identical to an in-process run of the same engine).
wire-smoke:
	cargo run --release -- netload --streams 4 --frames 80 --engine batch \
		--faults aggressive --cuts 4 --seed 7 --json wire_report.json

# The CI fleet path: the same contract held across a session-affine
# router over a 2-shard fleet, under aggressive faults PLUS one
# scheduled mid-run shard kill (the killed shard's sessions are
# re-driven from the router's frame bank). See docs/OPERATIONS.md.
fleet-smoke:
	cargo run --release -- netload --streams 4 --frames 80 --engine batch \
		--router 2 --kills 1 --faults aggressive --cuts 3 --seed 7 \
		--json fleet_report.json

# Verify every relative markdown link in the repo's docs resolves
# (same check CI's docs job runs).
check-links:
	python3 tools/check_md_links.py

# The CI ingest path: the seeded parser fuzzer, then the convert CLI
# re-serializes the checked-in fixtures onto themselves (byte identity
# pinned by `git diff --exit-code`), then a real tracked+scored run of
# `track --input` over the same fixtures.
INGEST_FIXTURES = rust/tests/fixtures/ingest
ingest-smoke:
	cargo run --release -- ingest-fuzz --iters 10000 --seed 7
	cargo run --release -- convert --input $(INGEST_FIXTURES)/tiny.det.txt \
		--to coco --out $(INGEST_FIXTURES)/tiny.coco.json
	cargo run --release -- convert --input $(INGEST_FIXTURES)/tiny.coco.json \
		--to mot --out $(INGEST_FIXTURES)/tiny.det.txt
	cargo run --release -- convert --input $(INGEST_FIXTURES)/tiny.gt.txt \
		--to mot-gt --out $(INGEST_FIXTURES)/tiny.gt.txt
	git diff --exit-code $(INGEST_FIXTURES)
	cargo run --release -- track --input $(INGEST_FIXTURES)/tiny.det.txt \
		--format auto --gt $(INGEST_FIXTURES)/tiny.gt.txt --engine batch

# Regenerate the checked-in baseline. The measured numbers come from
# THIS machine — review before committing and lower the fps medians to
# conservative floors (the gate margin only absorbs ~3x machine
# variance; the baseline's design is "any healthy build clears it").
lab-baseline:
	cargo run --release -- lab run --smoke --json artifacts/bench_baseline.json
	@echo "NOTE: artifacts/bench_baseline.json now holds numbers measured on THIS"
	@echo "machine. Review and floor the fps medians before committing (see"
	@echo "README 'Performance tracking')."

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings
