"""Baseline: the *original-style* Python SORT the paper compares against.

A faithful numpy reimplementation of abewley/sort (Bewley et al., ICIP'16)
— the comparator for Table V.  Semantics match the original:

  * filterpy-style KalmanFilter (predict: x=Fx, P=FPF'+Q; update with
    Joseph-form covariance), 7-state constant-velocity bbox model;
  * sklearn/scipy linear_assignment on the negated IoU matrix;
  * KalmanBoxTracker lifecycle with max_age / min_hits / hit_streak.

It is used in two places, both *off* the request path:
  1. `make artifacts` runs it on a deterministic mini-sequence to export
     golden end-to-end tracks for the Rust integration tests;
  2. the Table V bench (`cargo bench --bench table5_speedup`) invokes it
     as a subprocess on the full synthetic MOT suite and compares FPS
     against the Rust implementation.

CLI:  python baseline/sort_python.py SEQ_DIR [SEQ_DIR...] [--out OUT_DIR]
      prints a one-line JSON timing record to stdout.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
from scipy.optimize import linear_sum_assignment


def linear_assignment(cost_matrix):
    """sklearn.utils.linear_assignment_-compatible wrapper."""
    x, y = linear_sum_assignment(cost_matrix)
    return np.array(list(zip(x, y)))


def iou_batch(bb_test, bb_gt):
    """IoU between two sets of boxes [x1,y1,x2,y2]: (D,4) x (T,4) -> (D,T)."""
    bb_gt = np.expand_dims(bb_gt, 0)
    bb_test = np.expand_dims(bb_test, 1)

    xx1 = np.maximum(bb_test[..., 0], bb_gt[..., 0])
    yy1 = np.maximum(bb_test[..., 1], bb_gt[..., 1])
    xx2 = np.minimum(bb_test[..., 2], bb_gt[..., 2])
    yy2 = np.minimum(bb_test[..., 3], bb_gt[..., 3])
    w = np.maximum(0.0, xx2 - xx1)
    h = np.maximum(0.0, yy2 - yy1)
    wh = w * h
    o = wh / (
        (bb_test[..., 2] - bb_test[..., 0]) * (bb_test[..., 3] - bb_test[..., 1])
        + (bb_gt[..., 2] - bb_gt[..., 0]) * (bb_gt[..., 3] - bb_gt[..., 1])
        - wh
    )
    return o


def convert_bbox_to_z(bbox):
    """[x1,y1,x2,y2] -> [u,v,s,r] column vector."""
    w = bbox[2] - bbox[0]
    h = bbox[3] - bbox[1]
    x = bbox[0] + w / 2.0
    y = bbox[1] + h / 2.0
    s = w * h
    r = w / float(h)
    return np.array([x, y, s, r]).reshape((4, 1))


def convert_x_to_bbox(x, score=None):
    """[u,v,s,r,...] -> [x1,y1,x2,y2]."""
    w = np.sqrt(x[2] * x[3])
    h = x[2] / w
    if score is None:
        return np.array(
            [x[0] - w / 2.0, x[1] - h / 2.0, x[0] + w / 2.0, x[1] + h / 2.0]
        ).reshape((1, 4))
    return np.array(
        [x[0] - w / 2.0, x[1] - h / 2.0, x[0] + w / 2.0, x[1] + h / 2.0, score]
    ).reshape((1, 5))


class KalmanFilter:
    """Minimal filterpy.kalman.KalmanFilter equivalent (numpy matrices)."""

    def __init__(self, dim_x, dim_z):
        self.dim_x = dim_x
        self.dim_z = dim_z
        self.x = np.zeros((dim_x, 1))
        self.P = np.eye(dim_x)
        self.Q = np.eye(dim_x)
        self.F = np.eye(dim_x)
        self.H = np.zeros((dim_z, dim_x))
        self.R = np.eye(dim_z)
        self._I = np.eye(dim_x)

    def predict(self):
        self.x = self.F @ self.x
        self.P = self.F @ self.P @ self.F.T + self.Q

    def update(self, z):
        y = z - self.H @ self.x
        PHT = self.P @ self.H.T
        S = self.H @ PHT + self.R
        K = PHT @ np.linalg.inv(S)
        self.x = self.x + K @ y
        I_KH = self._I - K @ self.H
        # Joseph form, as filterpy's default update computes it.
        self.P = I_KH @ self.P @ I_KH.T + K @ self.R @ K.T


class KalmanBoxTracker:
    """Internal state of an individual tracked object (bbox)."""

    count = 0

    def __init__(self, bbox):
        self.kf = KalmanFilter(dim_x=7, dim_z=4)
        self.kf.F = np.array(
            [
                [1, 0, 0, 0, 1, 0, 0],
                [0, 1, 0, 0, 0, 1, 0],
                [0, 0, 1, 0, 0, 0, 1],
                [0, 0, 0, 1, 0, 0, 0],
                [0, 0, 0, 0, 1, 0, 0],
                [0, 0, 0, 0, 0, 1, 0],
                [0, 0, 0, 0, 0, 0, 1],
            ],
            dtype=float,
        )
        self.kf.H = np.array(
            [
                [1, 0, 0, 0, 0, 0, 0],
                [0, 1, 0, 0, 0, 0, 0],
                [0, 0, 1, 0, 0, 0, 0],
                [0, 0, 0, 1, 0, 0, 0],
            ],
            dtype=float,
        )
        self.kf.R[2:, 2:] *= 10.0
        self.kf.P[4:, 4:] *= 1000.0
        self.kf.P *= 10.0
        self.kf.Q[-1, -1] *= 0.01
        self.kf.Q[4:, 4:] *= 0.01

        self.kf.x[:4] = convert_bbox_to_z(bbox)
        self.time_since_update = 0
        self.id = KalmanBoxTracker.count
        KalmanBoxTracker.count += 1
        self.history = []
        self.hits = 0
        self.hit_streak = 0
        self.age = 0

    def update(self, bbox):
        self.time_since_update = 0
        self.history = []
        self.hits += 1
        self.hit_streak += 1
        self.kf.update(convert_bbox_to_z(bbox))

    def predict(self):
        if (self.kf.x[6] + self.kf.x[2]) <= 0:
            self.kf.x[6] *= 0.0
        self.kf.predict()
        self.age += 1
        if self.time_since_update > 0:
            self.hit_streak = 0
        self.time_since_update += 1
        self.history.append(convert_x_to_bbox(self.kf.x))
        return self.history[-1]

    def get_state(self):
        return convert_x_to_bbox(self.kf.x)


def associate_detections_to_trackers(detections, trackers, iou_threshold=0.3):
    """Assign detections to tracked objects (both as [x1,y1,x2,y2] boxes)."""
    if len(trackers) == 0:
        return (
            np.empty((0, 2), dtype=int),
            np.arange(len(detections)),
            np.empty((0, 5), dtype=int),
        )

    iou_matrix = iou_batch(detections, trackers)

    if min(iou_matrix.shape) > 0:
        a = (iou_matrix > iou_threshold).astype(np.int32)
        if a.sum(1).max() == 1 and a.sum(0).max() == 1:
            matched_indices = np.stack(np.where(a), axis=1)
        else:
            matched_indices = linear_assignment(-iou_matrix)
    else:
        matched_indices = np.empty(shape=(0, 2))

    unmatched_detections = [
        d for d in range(len(detections)) if d not in matched_indices[:, 0]
    ]
    unmatched_trackers = [
        t for t in range(len(trackers)) if t not in matched_indices[:, 1]
    ]

    matches = []
    for m in matched_indices:
        if iou_matrix[m[0], m[1]] < iou_threshold:
            unmatched_detections.append(m[0])
            unmatched_trackers.append(m[1])
        else:
            matches.append(m.reshape(1, 2))
    if len(matches) == 0:
        matches = np.empty((0, 2), dtype=int)
    else:
        matches = np.concatenate(matches, axis=0)

    return matches, np.array(unmatched_detections), np.array(unmatched_trackers)


class Sort:
    def __init__(self, max_age=1, min_hits=3, iou_threshold=0.3):
        self.max_age = max_age
        self.min_hits = min_hits
        self.iou_threshold = iou_threshold
        self.trackers = []
        self.frame_count = 0

    def update(self, dets=np.empty((0, 5))):
        """Process one frame; dets is (N,5) [x1,y1,x2,y2,score].

        Must be called once per frame even with empty detections.
        Returns (M,5) [x1,y1,x2,y2,track_id].
        """
        self.frame_count += 1
        trks = np.zeros((len(self.trackers), 5))
        to_del = []
        ret = []
        for t, trk in enumerate(trks):
            pos = self.trackers[t].predict()[0]
            trk[:] = [pos[0], pos[1], pos[2], pos[3], 0]
            if np.any(np.isnan(pos)):
                to_del.append(t)
        trks = np.ma.compress_rows(np.ma.masked_invalid(trks))
        for t in reversed(to_del):
            self.trackers.pop(t)
        matched, unmatched_dets, unmatched_trks = associate_detections_to_trackers(
            dets[:, :4], trks[:, :4], self.iou_threshold
        )

        for m in matched:
            self.trackers[m[1]].update(dets[m[0], :4])

        for i in unmatched_dets:
            trk = KalmanBoxTracker(dets[i, :4])
            self.trackers.append(trk)

        i = len(self.trackers)
        for trk in reversed(self.trackers):
            d = trk.get_state()[0]
            if (trk.time_since_update < 1) and (
                trk.hit_streak >= self.min_hits or self.frame_count <= self.min_hits
            ):
                ret.append(np.concatenate((d, [trk.id + 1])).reshape(1, -1))
            i -= 1
            if trk.time_since_update > self.max_age:
                self.trackers.pop(i)
        if len(ret) > 0:
            return np.concatenate(ret)
        return np.empty((0, 5))


# --------------------------------------------------------------------------
# CLI: run the tracker over MOT det.txt sequences, report timing.
# --------------------------------------------------------------------------


def load_mot_dets(path):
    """MOT det.txt -> dict frame -> (N,5) [x1,y1,x2,y2,score]."""
    data = np.loadtxt(path, delimiter=",", ndmin=2)
    frames = {}
    if data.size == 0:
        return frames
    for row in data:
        f = int(row[0])
        x, y, w, h, score = row[2], row[3], row[4], row[5], row[6]
        det = np.array([x, y, x + w, y + h, score])
        frames.setdefault(f, []).append(det)
    return {f: np.array(v) for f, v in frames.items()}


def run_sequence(det_path, out_path=None):
    """Track one sequence; returns (n_frames, seconds_in_update)."""
    frames = load_mot_dets(det_path)
    if not frames:
        return 0, 0.0
    max_frame = max(frames)
    tracker = Sort(max_age=1, min_hits=3, iou_threshold=0.3)
    out_lines = []
    total = 0.0
    for f in range(1, max_frame + 1):
        dets = frames.get(f, np.empty((0, 5)))
        t0 = time.perf_counter()
        tracks = tracker.update(dets)
        total += time.perf_counter() - t0
        if out_path is not None:
            for d in tracks:
                out_lines.append(
                    "%d,%d,%.2f,%.2f,%.2f,%.2f,1,-1,-1,-1"
                    % (f, d[4], d[0], d[1], d[2] - d[0], d[3] - d[1])
                )
    if out_path is not None:
        with open(out_path, "w") as fh:
            fh.write("\n".join(out_lines))
    return max_frame, total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("seqs", nargs="+", help="det.txt files")
    ap.add_argument("--out", default=None, help="directory for track output")
    args = ap.parse_args()

    total_frames, total_time = 0, 0.0
    for det in args.seqs:
        out = None
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            out = os.path.join(
                args.out, os.path.basename(os.path.dirname(det) or det) + ".txt"
            )
        nf, tt = run_sequence(det, out)
        total_frames += nf
        total_time += tt

    print(
        json.dumps(
            {
                "impl": "python-baseline",
                "frames": total_frames,
                "seconds": total_time,
                "fps": total_frames / total_time if total_time > 0 else 0.0,
            }
        )
    )


if __name__ == "__main__":
    main()
