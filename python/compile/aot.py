"""AOT compile path: lower the L2 tracker-bank graphs to HLO text.

Run once by ``make artifacts``; Python never runs on the request path.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.

Outputs (under artifacts/):
  bank_predict_iou.hlo.txt   T=16,D=16 fused predict + bbox + IoU matrix
  bank_update.hlo.txt        T=16 masked Joseph-form update
  bank_predict_T{n}.hlo.txt  bare predict at bank sizes for the E8 ablation
  parity.json                golden KF trajectory + IoU matrix (from ref.py)
                             consumed by the Rust unit tests
  golden_tracks.json         end-to-end SORT output of the python baseline
                             on a deterministic mini-sequence, consumed by
                             the Rust integration tests
  manifest.json              artifact index with shapes/dtypes
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import ref  # noqa: E402

PREDICT_SWEEP_T = [1, 4, 16, 64, 256]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default elides
    # dense array constants as `constant({...})`, which the xla crate's
    # text parser silently reconstructs as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def lower_artifacts(outdir: str) -> dict:
    """Lower every L2 entry point; return the manifest fragment."""
    arts = {}

    lowered = jax.jit(model.bank_predict_iou).lower(*model.example_args())
    path = os.path.join(outdir, "bank_predict_iou.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    arts["bank_predict_iou"] = {
        "file": "bank_predict_iou.hlo.txt",
        "t": model.BANK_T,
        "d": model.BANK_D,
        "inputs": [
            ["x", [model.BANK_T, 7]],
            ["p", [model.BANK_T, 7, 7]],
            ["mask", [model.BANK_T, 1]],
            ["dets", [model.BANK_D, 4]],
            ["dmask", [model.BANK_D, 1]],
        ],
        "outputs": [
            ["x", [model.BANK_T, 7]],
            ["p", [model.BANK_T, 7, 7]],
            ["boxes", [model.BANK_T, 4]],
            ["iou", [model.BANK_D, model.BANK_T]],
        ],
    }

    lowered = jax.jit(model.bank_update).lower(*model.example_update_args())
    path = os.path.join(outdir, "bank_update.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    arts["bank_update"] = {
        "file": "bank_update.hlo.txt",
        "t": model.BANK_T,
        "inputs": [
            ["x", [model.BANK_T, 7]],
            ["p", [model.BANK_T, 7, 7]],
            ["z", [model.BANK_T, 4]],
            ["zmask", [model.BANK_T, 1]],
        ],
        "outputs": [
            ["x", [model.BANK_T, 7]],
            ["p", [model.BANK_T, 7, 7]],
        ],
    }

    for t in PREDICT_SWEEP_T:
        lowered = jax.jit(model.bank_predict_only).lower(
            jax.ShapeDtypeStruct((t, 7), jnp.float64),
            jax.ShapeDtypeStruct((t, 7, 7), jnp.float64),
            jax.ShapeDtypeStruct((t, 1), jnp.float64),
        )
        name = f"bank_predict_T{t}"
        with open(os.path.join(outdir, name + ".hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        arts[name] = {
            "file": name + ".hlo.txt",
            "t": t,
            "inputs": [["x", [t, 7]], ["p", [t, 7, 7]], ["mask", [t, 1]]],
            "outputs": [["x", [t, 7]], ["p", [t, 7, 7]]],
        }

    return arts


# --------------------------------------------------------------------------
# Golden data for the Rust tests.
# --------------------------------------------------------------------------


def _mini_scenario(steps: int = 12):
    """Deterministic measurements for 3 objects moving linearly."""
    seeds = np.array(
        [
            [10.0, 20.0, 60.0, 140.0],
            [200.0, 50.0, 260.0, 170.0],
            [400.0, 300.0, 470.0, 420.0],
        ]
    )
    vel = np.array([[3.0, 1.5], [-2.0, 0.5], [1.0, -2.0]])
    frames = []
    for k in range(steps):
        boxes = seeds.copy()
        boxes[:, 0] += vel[:, 0] * k
        boxes[:, 2] += vel[:, 0] * k
        boxes[:, 1] += vel[:, 1] * k
        boxes[:, 3] += vel[:, 1] * k
        # mild deterministic "detector jitter"
        boxes[:, 0] += 0.3 * np.sin(0.7 * k + np.arange(3))
        boxes[:, 2] += 0.2 * np.cos(0.5 * k + np.arange(3))
        frames.append(boxes)
    return frames


def export_parity(outdir: str) -> None:
    """Golden Kalman trajectory + IoU matrix from the jnp oracle."""
    frames = _mini_scenario()
    t = 3
    x = np.zeros((t, 7))
    p = np.zeros((t, 7, 7))
    for i in range(t):
        z0 = np.asarray(ref.bbox_to_z(jnp.asarray(frames[0][i])))
        xi, pi = ref.new_tracker_state(jnp.asarray(z0))
        x[i], p[i] = np.asarray(xi), np.asarray(pi)
    mask = np.ones((t, 1))

    steps = []
    for k in range(1, len(frames)):
        xn, pn = ref.predict_ref(jnp.asarray(x), jnp.asarray(p), jnp.asarray(mask))
        x_pred, p_pred = np.asarray(xn), np.asarray(pn)
        z = np.asarray(ref.bbox_to_z(jnp.asarray(frames[k])))
        xu, pu = ref.update_ref(
            jnp.asarray(x_pred), jnp.asarray(p_pred), jnp.asarray(z), jnp.asarray(mask)
        )
        x, p = np.asarray(xu), np.asarray(pu)
        steps.append(
            {
                "frame": k,
                "z": z.tolist(),
                "x_pred": x_pred.tolist(),
                "p_pred_diag": [np.diag(p_pred[i]).tolist() for i in range(t)],
                "x_post": x.tolist(),
                "p_post": [p[i].tolist() for i in range(t)],
            }
        )

    dets = np.array(
        [
            [0.0, 0.0, 10.0, 10.0],
            [5.0, 5.0, 15.0, 15.0],
            [100.0, 100.0, 120.0, 140.0],
            [0.0, 0.0, 0.0, 0.0],
        ]
    )
    boxes = np.array(
        [
            [0.0, 0.0, 10.0, 10.0],
            [8.0, 8.0, 18.0, 18.0],
            [95.0, 110.0, 125.0, 150.0],
        ]
    )
    iou = np.asarray(ref.iou_ref(jnp.asarray(dets), jnp.asarray(boxes)))

    parity = {
        "description": "golden SORT KF trajectory (ref.py oracle); "
        "consumed by rust/src/sort tests",
        "constants": {
            "F": np.asarray(ref.F).tolist(),
            "H": np.asarray(ref.H).tolist(),
            "Q": np.asarray(ref.Q).tolist(),
            "R": np.asarray(ref.R).tolist(),
            "P0": np.asarray(ref.P0).tolist(),
        },
        "seed_boxes": [f.tolist() for f in _mini_scenario(1)],
        "frames": [f.tolist() for f in _mini_scenario()],
        "steps": steps,
        "iou_case": {
            "dets": dets.tolist(),
            "boxes": boxes.tolist(),
            "iou": iou.tolist(),
        },
    }
    with open(os.path.join(outdir, "parity.json"), "w") as f:
        json.dump(parity, f)


def export_golden_tracks(outdir: str) -> None:
    """Run the python baseline SORT on the mini scenario; dump its output."""
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from baseline.sort_python import Sort  # noqa: E402

    frames = _mini_scenario()
    tracker = Sort(max_age=1, min_hits=3, iou_threshold=0.3)
    out = []
    for boxes in frames:
        dets = np.hstack([boxes, np.ones((boxes.shape[0], 1))])  # score col
        tracks = tracker.update(dets)
        out.append(tracks.tolist())
    with open(os.path.join(outdir, "golden_tracks.json"), "w") as f:
        json.dump(
            {
                "params": {"max_age": 1, "min_hits": 3, "iou_threshold": 0.3},
                "frames": [f.tolist() for f in frames],
                "tracks": out,
            },
            f,
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    arts = lower_artifacts(args.outdir)
    export_parity(args.outdir)
    export_golden_tracks(args.outdir)

    manifest = {
        "dtype": "f64",
        "dim_x": 7,
        "dim_z": 4,
        "bank_t": model.BANK_T,
        "bank_d": model.BANK_D,
        "artifacts": arts,
    }
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(arts)} HLO artifacts + parity/golden/manifest to {args.outdir}")


if __name__ == "__main__":
    main()
