"""L2: the SORT tracker-bank compute graph (JAX, build-time only).

SORT's per-frame numeric work, reformulated as a fixed-shape *bank* of T
tracker slots so that it AOT-compiles to static HLO the Rust coordinator
can execute.  The control-flow-heavy parts of SORT (Hungarian assignment,
tracker lifecycle) stay in Rust (L3); this module owns the dense algebra:

  frame step =  bank_predict_iou  ->  [rust: associate]  ->  bank_update

Both entry points call the Pallas kernels (L1) and add the pure-jnp glue
XLA fuses around them (bbox conversion, masking).  Dead slots are carried
through untouched so the Rust side can keep a stable slot <-> tracker id
mapping.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import iou as iou_kernel
from .kernels import kalman as kalman_kernel
from .kernels import ref

DIM_X = ref.DIM_X
DIM_Z = ref.DIM_Z

# Default bank geometry.  Table I's max simultaneous object count is 13;
# 16 gives headroom and a power-of-two batch tile.
BANK_T = 16
BANK_D = 16


def bank_predict_iou(x, p, mask, dets, dmask):
    """Predict every live tracker slot and score it against detections.

    Inputs:
      x     (T,7)    tracker states
      p     (T,7,7)  covariances
      mask  (T,1)    1.0 = live slot
      dets  (D,4)    detection boxes [x1,y1,x2,y2] (padded rows arbitrary)
      dmask (D,1)    1.0 = real detection

    Outputs:
      xn    (T,7)    predicted states
      pn    (T,7,7)  predicted covariances
      boxes (T,4)    predicted boxes (dead slots: 0)
      iou   (D,T)    IoU cost matrix, zeroed on dead/padded pairs
    """
    xn, pn = kalman_kernel.predict(x, p, mask)
    boxes = ref.x_to_bbox(xn) * mask                 # (T,4); dead slots -> 0
    boxes = jnp.where(jnp.isfinite(boxes), boxes, 0.0)
    iou = iou_kernel.iou_matrix(dets, boxes)         # (D,T)
    iou = iou * dmask * mask[:, 0][None, :]
    return xn, pn, boxes, iou


def bank_update(x, p, z, zmask):
    """Measurement-update the matched slots; pass the rest through.

    z rows are [u,v,s,r] measurements (SORT's bbox_to_z form), produced by
    the Rust associator; zmask marks the matched slots.
    """
    return kalman_kernel.update(x, p, z, zmask)


def bank_predict_only(x, p, mask):
    """Bare batched predict — the unit used by the xla_vs_native crossover
    ablation (E8) at several bank sizes."""
    return kalman_kernel.predict(x, p, mask)


def example_args(t: int = BANK_T, d: int = BANK_D, dtype=jnp.float64):
    """ShapeDtypeStructs for AOT lowering of bank_predict_iou."""
    return (
        jax.ShapeDtypeStruct((t, DIM_X), dtype),
        jax.ShapeDtypeStruct((t, DIM_X, DIM_X), dtype),
        jax.ShapeDtypeStruct((t, 1), dtype),
        jax.ShapeDtypeStruct((d, DIM_Z), dtype),
        jax.ShapeDtypeStruct((d, 1), dtype),
    )


def example_update_args(t: int = BANK_T, dtype=jnp.float64):
    """ShapeDtypeStructs for AOT lowering of bank_update."""
    return (
        jax.ShapeDtypeStruct((t, DIM_X), dtype),
        jax.ShapeDtypeStruct((t, DIM_X, DIM_X), dtype),
        jax.ShapeDtypeStruct((t, DIM_Z), dtype),
        jax.ShapeDtypeStruct((t, 1), dtype),
    )
