"""Pallas kernels for the batched SORT Kalman tracker bank (L1).

The paper's thesis is that SORT's matrices are *extremely small* (7x7,
4x7, 4x4): one tracker cannot feed parallel hardware.  The profitable
axis is the batch of independent trackers/streams — the accelerator
analog of the paper's throughput scaling.  These kernels therefore
process a *bank* of T tracker slots, tiled over the batch dimension by
BlockSpec; within a block, every 7x7/4x4 operand lives in VMEM and the
batched matmuls map onto the MXU/VPU.

The kernels are lowered with ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls); real-TPU efficiency is estimated in
DESIGN.md from the BlockSpec footprint.

Correctness contract: bit-for-bit semantics of ``ref.py`` (same guard,
Joseph-form update), validated by ``python/tests/test_kalman_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DIM_X = ref.DIM_X
DIM_Z = ref.DIM_Z

# Default batch tile: 8 tracker slots per grid step.  8 x (7x7) f64
# covariances ≈ 3.1 KiB — tiny next to ~16 MiB VMEM, so the tile size is
# chosen for MXU occupancy of the batched matmul, not capacity.
DEFAULT_BLOCK_T = 8


def _block_t(t: int) -> int:
    """Largest tile <= DEFAULT_BLOCK_T that divides the bank size."""
    bt = min(DEFAULT_BLOCK_T, t)
    while t % bt != 0:
        bt -= 1
    return max(bt, 1)


# --------------------------------------------------------------------------
# predict
# --------------------------------------------------------------------------


def _predict_kernel(x_ref, p_ref, m_ref, f_ref, q_ref, xo_ref, po_ref):
    f = f_ref[...]          # (7, 7) constant, broadcast to every block
    q = q_ref[...]          # (7, 7)

    x = x_ref[...]          # (BT, 7)
    p = p_ref[...]          # (BT, 7, 7)
    m = m_ref[...]          # (BT, 1)

    # SORT's negative-area guard: if x[6] + x[2] <= 0 then x[6] <- 0.
    # Written as a column-mask select (TPU-friendly: no scatter).
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    guard = (x[:, 6:7] + x[:, 2:3] <= 0.0) & (col == DIM_X - 1)
    xg = jnp.where(guard, jnp.zeros_like(x), x)

    xn = xg @ f.T                                       # (BT,7)
    pn = jnp.matmul(jnp.matmul(f, p), f.T) + q          # (BT,7,7)

    xo_ref[...] = jnp.where(m > 0, xn, x)
    po_ref[...] = jnp.where(m[:, :, None] > 0, pn, p)


@functools.partial(jax.jit, static_argnames=("block_t",))
def predict(x, p, mask, *, block_t: int | None = None):
    """Batched SORT predict over a tracker bank.

    x: (T,7), p: (T,7,7), mask: (T,1).  Returns (x', P').
    """
    t = x.shape[0]
    bt = block_t or _block_t(t)
    dtype = x.dtype
    grid = (t // bt,)
    f = ref.F.astype(dtype)
    q = ref.Q.astype(dtype)
    return pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, DIM_X), lambda i: (i, 0)),
            pl.BlockSpec((bt, DIM_X, DIM_X), lambda i: (i, 0, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
            pl.BlockSpec((DIM_X, DIM_X), lambda i: (0, 0)),
            pl.BlockSpec((DIM_X, DIM_X), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, DIM_X), lambda i: (i, 0)),
            pl.BlockSpec((bt, DIM_X, DIM_X), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, DIM_X), dtype),
            jax.ShapeDtypeStruct((t, DIM_X, DIM_X), dtype),
        ],
        interpret=True,
    )(x, p, mask, f, q)


# --------------------------------------------------------------------------
# update
# --------------------------------------------------------------------------


def _inv2x2(m):
    """Closed-form batched 2x2 inverse: m is (..., 2, 2)."""
    a = m[..., 0:1, 0:1]
    b = m[..., 0:1, 1:2]
    c = m[..., 1:2, 0:1]
    d = m[..., 1:2, 1:2]
    det = a * d - b * c
    top = jnp.concatenate([d, -b], axis=-1)
    bot = jnp.concatenate([-c, a], axis=-1)
    return jnp.concatenate([top, bot], axis=-2) / det


def _inv4x4_spd(s):
    """Batched 4x4 SPD inverse via 2x2-block Schur complement.

    s: (..., 4, 4) symmetric positive definite.  This is the kernel-side
    stand-in for the paper's "cholesky/Inv" step: all arithmetic is
    batched 2x2 matmuls, which vectorize cleanly over the tracker bank.
    """
    a = s[..., :2, :2]
    b = s[..., :2, 2:]
    c = s[..., 2:, :2]
    d = s[..., 2:, 2:]
    ai = _inv2x2(a)
    schur = d - jnp.matmul(jnp.matmul(c, ai), b)
    si = _inv2x2(schur)
    aib = jnp.matmul(ai, b)          # (...,2,2)
    cai = jnp.matmul(c, ai)          # (...,2,2)
    tl = ai + jnp.matmul(jnp.matmul(aib, si), cai)
    tr = -jnp.matmul(aib, si)
    bl = -jnp.matmul(si, cai)
    top = jnp.concatenate([tl, tr], axis=-1)
    bot = jnp.concatenate([bl, si], axis=-1)
    return jnp.concatenate([top, bot], axis=-2)


def _update_kernel(x_ref, p_ref, z_ref, m_ref, h_ref, r_ref, xo_ref, po_ref):
    h = h_ref[...]          # (4, 7) constant, broadcast to every block
    r = r_ref[...]          # (4, 4)
    # I_7 built in-kernel from iota (no captured constants allowed).
    rows = jax.lax.broadcasted_iota(jnp.int32, (DIM_X, DIM_X), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (DIM_X, DIM_X), 1)
    eye = jnp.where(rows == cols, jnp.ones((), h.dtype), jnp.zeros((), h.dtype))

    x = x_ref[...]          # (BT,7)
    p = p_ref[...]          # (BT,7,7)
    z = z_ref[...]          # (BT,4)
    m = m_ref[...]          # (BT,1)

    y = z - x @ h.T                                  # (BT,4)
    pht = jnp.matmul(p, h.T)                         # (BT,7,4)
    s = jnp.matmul(h, pht) + r                       # (BT,4,4)
    sinv = _inv4x4_spd(s)                            # (BT,4,4)
    k = jnp.matmul(pht, sinv)                        # (BT,7,4)

    xn = x + jnp.matmul(k, y[:, :, None])[:, :, 0]
    ikh = eye - jnp.matmul(k, h)                     # (BT,7,7)
    pn = jnp.matmul(jnp.matmul(ikh, p), jnp.swapaxes(ikh, -1, -2)) + jnp.matmul(
        jnp.matmul(k, r), jnp.swapaxes(k, -1, -2)
    )

    xo_ref[...] = jnp.where(m > 0, xn, x)
    po_ref[...] = jnp.where(m[:, :, None] > 0, pn, p)


@functools.partial(jax.jit, static_argnames=("block_t",))
def update(x, p, z, zmask, *, block_t: int | None = None):
    """Batched SORT update (Joseph form) over a tracker bank.

    x: (T,7), p: (T,7,7), z: (T,4), zmask: (T,1).  Returns (x', P').
    """
    t = x.shape[0]
    bt = block_t or _block_t(t)
    dtype = x.dtype
    grid = (t // bt,)
    h = ref.H.astype(dtype)
    r = ref.R.astype(dtype)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, DIM_X), lambda i: (i, 0)),
            pl.BlockSpec((bt, DIM_X, DIM_X), lambda i: (i, 0, 0)),
            pl.BlockSpec((bt, DIM_Z), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
            pl.BlockSpec((DIM_Z, DIM_X), lambda i: (0, 0)),
            pl.BlockSpec((DIM_Z, DIM_Z), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, DIM_X), lambda i: (i, 0)),
            pl.BlockSpec((bt, DIM_X, DIM_X), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, DIM_X), dtype),
            jax.ShapeDtypeStruct((t, DIM_X, DIM_X), dtype),
        ],
        interpret=True,
    )(x, p, z, zmask, h, r)
