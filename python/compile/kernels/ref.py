"""Pure-jnp reference oracle for the SORT numeric core.

This module is the single source of truth for the paper's Kalman-filter
constants (the 7-state constant-velocity bounding-box model of SORT,
Bewley et al. 2016) and for the batched semantics the Pallas kernels must
match.  Everything here is written with plain jax.numpy ops — no Pallas —
so pytest can diff kernel outputs against it, and `aot.py` can export a
golden trajectory (`artifacts/parity.json`) that the Rust implementation
is unit-tested against.

State layout (SORT):  x = [u, v, s, r, du, dv, ds]
  u, v : bbox center;  s : scale (area);  r : aspect ratio (constant).
Measurement:          z = [u, v, s, r]
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# SORT Kalman constants (exactly abewley/sort's KalmanBoxTracker).
# --------------------------------------------------------------------------

DIM_X = 7
DIM_Z = 4


def _f_mat(dtype=jnp.float64) -> jnp.ndarray:
    """State transition: constant velocity, dt = 1."""
    f = np.eye(DIM_X)
    f[0, 4] = 1.0
    f[1, 5] = 1.0
    f[2, 6] = 1.0
    return jnp.asarray(f, dtype=dtype)


def _h_mat(dtype=jnp.float64) -> jnp.ndarray:
    """Measurement: observe [u, v, s, r]."""
    h = np.zeros((DIM_Z, DIM_X))
    for i in range(DIM_Z):
        h[i, i] = 1.0
    return jnp.asarray(h, dtype=dtype)


def _q_mat(dtype=jnp.float64) -> jnp.ndarray:
    """Process noise: Q = eye; Q[-1,-1] *= 0.01; Q[4:,4:] *= 0.01."""
    q = np.eye(DIM_X)
    q[-1, -1] *= 0.01
    q[4:, 4:] *= 0.01
    return jnp.asarray(q, dtype=dtype)


def _r_mat(dtype=jnp.float64) -> jnp.ndarray:
    """Measurement noise: R = eye; R[2:,2:] *= 10."""
    r = np.eye(DIM_Z)
    r[2:, 2:] *= 10.0
    return jnp.asarray(r, dtype=dtype)


def _p0_mat(dtype=jnp.float64) -> jnp.ndarray:
    """Initial covariance: P = eye; P[4:,4:] *= 1000; P *= 10."""
    p = np.eye(DIM_X)
    p[4:, 4:] *= 1000.0
    p *= 10.0
    return jnp.asarray(p, dtype=dtype)


F = _f_mat()
H = _h_mat()
Q = _q_mat()
R = _r_mat()
P0 = _p0_mat()

# --------------------------------------------------------------------------
# BBox conversions (SORT's convert_bbox_to_z / convert_x_to_bbox).
# --------------------------------------------------------------------------


def bbox_to_z(bbox: jnp.ndarray) -> jnp.ndarray:
    """[x1,y1,x2,y2] -> [u,v,s,r]; batched over leading dims."""
    bbox = jnp.asarray(bbox)
    w = bbox[..., 2] - bbox[..., 0]
    h = bbox[..., 3] - bbox[..., 1]
    u = bbox[..., 0] + w / 2.0
    v = bbox[..., 1] + h / 2.0
    s = w * h
    r = w / h
    return jnp.stack([u, v, s, r], axis=-1)


def x_to_bbox(x: jnp.ndarray) -> jnp.ndarray:
    """state (...,7) -> bbox [x1,y1,x2,y2]; batched over leading dims."""
    x = jnp.asarray(x)
    s = x[..., 2]
    r = x[..., 3]
    w = jnp.sqrt(s * r)
    h = s / w
    return jnp.stack(
        [
            x[..., 0] - w / 2.0,
            x[..., 1] - h / 2.0,
            x[..., 0] + w / 2.0,
            x[..., 1] + h / 2.0,
        ],
        axis=-1,
    )


# --------------------------------------------------------------------------
# Batched Kalman predict / update over a tracker bank (T slots).
# --------------------------------------------------------------------------


def predict_ref(x, P, mask):
    """Batched SORT predict.

    x    : (T, 7)    states
    P    : (T, 7, 7) covariances
    mask : (T, 1)    1.0 for live slots, 0.0 for dead (passed through).

    Returns (x', P').  Implements SORT's negative-area guard:
    if x[6] + x[2] <= 0 then x[6] <- 0 before the linear predict.
    """
    x = jnp.asarray(x)
    P = jnp.asarray(P)
    mask = jnp.asarray(mask)
    f = F.astype(x.dtype)
    q = Q.astype(x.dtype)

    guard = x[:, 6] + x[:, 2] <= 0.0
    x6 = jnp.where(guard, 0.0, x[:, 6])
    xg = x.at[:, 6].set(x6)

    xn = xg @ f.T                                   # (T,7)
    Pn = jnp.matmul(jnp.matmul(f, P), f.T) + q      # (T,7,7)

    m1 = mask                                       # (T,1)
    m2 = mask[:, :, None]                           # (T,1,1)
    return jnp.where(m1 > 0, xn, x), jnp.where(m2 > 0, Pn, P)


def update_ref(x, P, z, zmask):
    """Batched SORT/filterpy update (Joseph-form covariance).

    x     : (T, 7)    predicted states
    P     : (T, 7, 7) predicted covariances
    z     : (T, 4)    measurements ([u,v,s,r]) for matched slots
    zmask : (T, 1)    1.0 where a measurement exists.

    y = z - Hx;  S = HPH' + R;  K = PH'S^-1
    x' = x + Ky;  P' = (I-KH)P(I-KH)' + KRK'
    """
    x = jnp.asarray(x)
    P = jnp.asarray(P)
    z = jnp.asarray(z)
    zmask = jnp.asarray(zmask)
    h = H.astype(x.dtype)
    r = R.astype(x.dtype)
    eye = jnp.eye(DIM_X, dtype=x.dtype)

    y = z - x @ h.T                                 # (T,4)
    PHt = jnp.matmul(P, h.T)                        # (T,7,4)
    S = jnp.matmul(h, PHt) + r                      # (T,4,4)
    Sinv = jnp.linalg.inv(S)                        # (T,4,4)
    K = jnp.matmul(PHt, Sinv)                       # (T,7,4)

    xn = x + jnp.matmul(K, y[:, :, None])[:, :, 0]  # (T,7)
    IKH = eye - jnp.matmul(K, h)                    # (T,7,7)
    Pn = jnp.matmul(jnp.matmul(IKH, P), jnp.swapaxes(IKH, -1, -2)) + jnp.matmul(
        jnp.matmul(K, r), jnp.swapaxes(K, -1, -2)
    )

    m1 = zmask
    m2 = zmask[:, :, None]
    return jnp.where(m1 > 0, xn, x), jnp.where(m2 > 0, Pn, P)


def iou_ref(dets, boxes):
    """IoU matrix between detections (D,4) and tracker boxes (T,4).

    Boxes are [x1,y1,x2,y2].  Degenerate/empty overlaps yield IoU 0.
    """
    dets = jnp.asarray(dets)
    boxes = jnp.asarray(boxes)
    d = dets[:, None, :]    # (D,1,4)
    t = boxes[None, :, :]   # (1,T,4)

    xx1 = jnp.maximum(d[..., 0], t[..., 0])
    yy1 = jnp.maximum(d[..., 1], t[..., 1])
    xx2 = jnp.minimum(d[..., 2], t[..., 2])
    yy2 = jnp.minimum(d[..., 3], t[..., 3])
    w = jnp.maximum(0.0, xx2 - xx1)
    h = jnp.maximum(0.0, yy2 - yy1)
    inter = w * h
    area_d = (d[..., 2] - d[..., 0]) * (d[..., 3] - d[..., 1])
    area_t = (t[..., 2] - t[..., 0]) * (t[..., 3] - t[..., 1])
    union = area_d + area_t - inter
    return jnp.where(union > 0, inter / jnp.where(union > 0, union, 1.0), 0.0)


def new_tracker_state(z):
    """Initial (x, P) for a fresh tracker seeded by measurement z=(4,)."""
    z = jnp.asarray(z)
    x = jnp.concatenate([z, jnp.zeros((3,), dtype=z.dtype)])
    return x, P0.astype(z.dtype)
