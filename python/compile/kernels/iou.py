"""Pallas kernel for the batched IoU cost matrix (L1).

SORT's assignment step scores every (detection, tracker) pair by
bounding-box intersection-over-union.  D and T are tiny (<= 16 after
padding: Table I's max object count is 13), so the whole (D,T) tile is a
single VMEM block; the kernel exists to fuse the pairwise geometry into
one pass instead of 9+ elementwise library calls (Table II's
"element-wise Matrix-Matrix ... size varies 1x10 to 13x10" row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _iou_kernel(d_ref, t_ref, o_ref):
    d = d_ref[...][:, None, :]   # (D,1,4)
    t = t_ref[...][None, :, :]   # (1,T,4)

    xx1 = jnp.maximum(d[..., 0], t[..., 0])
    yy1 = jnp.maximum(d[..., 1], t[..., 1])
    xx2 = jnp.minimum(d[..., 2], t[..., 2])
    yy2 = jnp.minimum(d[..., 3], t[..., 3])
    w = jnp.maximum(0.0, xx2 - xx1)
    h = jnp.maximum(0.0, yy2 - yy1)
    inter = w * h
    area_d = (d[..., 2] - d[..., 0]) * (d[..., 3] - d[..., 1])
    area_t = (t[..., 2] - t[..., 0]) * (t[..., 3] - t[..., 1])
    union = area_d + area_t - inter
    o_ref[...] = jnp.where(union > 0, inter / jnp.where(union > 0, union, 1.0), 0.0)


@jax.jit
def iou_matrix(dets, boxes):
    """IoU matrix: dets (D,4) x boxes (T,4) -> (D,T)."""
    d, t = dets.shape[0], boxes.shape[0]
    return pl.pallas_call(
        _iou_kernel,
        out_shape=jax.ShapeDtypeStruct((d, t), dets.dtype),
        interpret=True,
    )(dets, boxes)
