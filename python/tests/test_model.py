"""L2: tracker-bank graph semantics + AOT lowering smoke tests."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def bank_inputs(rng, t=model.BANK_T, d=model.BANK_D, live_t=5, live_d=4):
    x = np.zeros((t, 7))
    p = np.tile(np.asarray(ref.P0)[None], (t, 1, 1))
    mask = np.zeros((t, 1))
    for i in range(live_t):
        x[i, 0] = 100.0 + 50 * i
        x[i, 1] = 100.0 + 30 * i
        x[i, 2] = 2000.0 + 100 * i
        x[i, 3] = 0.5
        mask[i, 0] = 1.0
    dets = np.zeros((d, 4))
    dmask = np.zeros((d, 1))
    for j in range(live_d):
        cx, cy = 100.0 + 50 * j, 100.0 + 30 * j
        dets[j] = [cx - 20, cy - 30, cx + 20, cy + 30]
        dmask[j, 0] = 1.0
    return x, p, mask, dets, dmask


def test_bank_predict_iou_shapes_and_masking():
    rng = np.random.default_rng(0)
    x, p, mask, dets, dmask = bank_inputs(rng)
    xn, pn, boxes, iou = model.bank_predict_iou(
        *(jnp.asarray(a) for a in (x, p, mask, dets, dmask))
    )
    xn, pn, boxes, iou = map(np.asarray, (xn, pn, boxes, iou))
    assert xn.shape == (model.BANK_T, 7)
    assert pn.shape == (model.BANK_T, 7, 7)
    assert boxes.shape == (model.BANK_T, 4)
    assert iou.shape == (model.BANK_D, model.BANK_T)
    # dead tracker slots: untouched state, zero box, zero iou column
    np.testing.assert_array_equal(xn[5:], x[5:])
    np.testing.assert_array_equal(boxes[5:], 0.0)
    np.testing.assert_array_equal(iou[:, 5:], 0.0)
    # padded detection rows: zero iou row
    np.testing.assert_array_equal(iou[4:, :], 0.0)
    assert np.all(np.isfinite(boxes)) and np.all(np.isfinite(iou))


def test_bank_predict_iou_matches_oracle_on_live_block():
    rng = np.random.default_rng(1)
    x, p, mask, dets, dmask = bank_inputs(rng)
    xn, pn, boxes, iou = model.bank_predict_iou(
        *(jnp.asarray(a) for a in (x, p, mask, dets, dmask))
    )
    xr, pr = ref.predict_ref(jnp.asarray(x), jnp.asarray(p), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(xn), np.asarray(xr), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(pn), np.asarray(pr), rtol=1e-12)
    boxes_ref = np.asarray(ref.x_to_bbox(xr))[:5]
    np.testing.assert_allclose(np.asarray(boxes)[:5], boxes_ref, rtol=1e-12)
    iou_ref_m = np.asarray(ref.iou_ref(jnp.asarray(dets[:4]), jnp.asarray(boxes_ref)))
    np.testing.assert_allclose(np.asarray(iou)[:4, :5], iou_ref_m, rtol=1e-12)


def test_bank_update_matches_oracle():
    rng = np.random.default_rng(2)
    x, p, mask, dets, dmask = bank_inputs(rng)
    z = np.zeros((model.BANK_T, 4))
    z[:5] = np.asarray(ref.bbox_to_z(jnp.asarray(dets[:4])))[:4].sum() * 0 + 1.0
    z[0] = [100.0, 100.0, 2400.0, 0.66]
    zmask = mask.copy()
    xu, pu = model.bank_update(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(z), jnp.asarray(zmask)
    )
    xr, pr = ref.update_ref(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(z), jnp.asarray(zmask)
    )
    np.testing.assert_allclose(np.asarray(xu), np.asarray(xr), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(pu), np.asarray(pr), rtol=1e-9, atol=1e-9)


def test_lowering_emits_hlo_text():
    from compile.aot import to_hlo_text

    lowered = jax.jit(model.bank_predict_iou).lower(*model.example_args())
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f64" in text

    lowered = jax.jit(model.bank_update).lower(*model.example_update_args())
    text = to_hlo_text(lowered)
    assert "ENTRY" in text


def test_lowering_is_deterministic():
    from compile.aot import to_hlo_text

    lowered1 = jax.jit(model.bank_update).lower(*model.example_update_args())
    lowered2 = jax.jit(model.bank_update).lower(*model.example_update_args())
    assert to_hlo_text(lowered1) == to_hlo_text(lowered2)
