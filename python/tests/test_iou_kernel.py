"""L1 correctness: the Pallas IoU kernel vs the oracle and vs numpy."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import iou, ref


def np_iou(dets, boxes):
    """Independent numpy formulation (the baseline's iou_batch)."""
    d = dets[:, None, :]
    t = boxes[None, :, :]
    xx1 = np.maximum(d[..., 0], t[..., 0])
    yy1 = np.maximum(d[..., 1], t[..., 1])
    xx2 = np.minimum(d[..., 2], t[..., 2])
    yy2 = np.minimum(d[..., 3], t[..., 3])
    w = np.maximum(0.0, xx2 - xx1)
    h = np.maximum(0.0, yy2 - yy1)
    inter = w * h
    union = (
        (d[..., 2] - d[..., 0]) * (d[..., 3] - d[..., 1])
        + (t[..., 2] - t[..., 0]) * (t[..., 3] - t[..., 1])
        - inter
    )
    out = np.zeros_like(inter)
    nz = union > 0
    out[nz] = inter[nz] / union[nz]
    return out


def rand_boxes(rng, n):
    x1 = rng.uniform(0, 1800, n)
    y1 = rng.uniform(0, 1000, n)
    w = rng.uniform(1, 300, n)
    h = rng.uniform(1, 300, n)
    return np.stack([x1, y1, x1 + w, y1 + h], axis=1)


@settings(max_examples=30, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=16),
    t=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_iou_matches_ref_and_numpy(d, t, seed):
    rng = np.random.default_rng(seed)
    dets, boxes = rand_boxes(rng, d), rand_boxes(rng, t)
    got = np.asarray(iou.iou_matrix(jnp.asarray(dets), jnp.asarray(boxes)))
    want_ref = np.asarray(ref.iou_ref(jnp.asarray(dets), jnp.asarray(boxes)))
    want_np = np_iou(dets, boxes)
    np.testing.assert_allclose(got, want_ref, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(got, want_np, rtol=1e-12, atol=1e-12)


def test_iou_identity():
    b = np.array([[0.0, 0.0, 10.0, 10.0], [5.0, 5.0, 25.0, 30.0]])
    got = np.asarray(iou.iou_matrix(jnp.asarray(b), jnp.asarray(b)))
    np.testing.assert_allclose(np.diag(got), [1.0, 1.0], rtol=1e-12)


def test_iou_disjoint_is_zero():
    a = np.array([[0.0, 0.0, 10.0, 10.0]])
    b = np.array([[20.0, 20.0, 30.0, 30.0]])
    got = np.asarray(iou.iou_matrix(jnp.asarray(a), jnp.asarray(b)))
    assert got[0, 0] == 0.0


def test_iou_touching_edges_is_zero():
    a = np.array([[0.0, 0.0, 10.0, 10.0]])
    b = np.array([[10.0, 0.0, 20.0, 10.0]])
    got = np.asarray(iou.iou_matrix(jnp.asarray(a), jnp.asarray(b)))
    assert got[0, 0] == 0.0


def test_iou_degenerate_zero_area_boxes():
    """Zero-area padding rows must produce IoU 0, not NaN."""
    a = np.array([[0.0, 0.0, 0.0, 0.0]])
    b = np.array([[0.0, 0.0, 0.0, 0.0], [1.0, 1.0, 5.0, 5.0]])
    got = np.asarray(iou.iou_matrix(jnp.asarray(a), jnp.asarray(b)))
    assert np.all(np.isfinite(got))
    np.testing.assert_array_equal(got, np.zeros((1, 2)))


def test_iou_half_overlap():
    a = np.array([[0.0, 0.0, 10.0, 10.0]])
    b = np.array([[0.0, 5.0, 10.0, 15.0]])
    got = np.asarray(iou.iou_matrix(jnp.asarray(a), jnp.asarray(b)))
    assert got[0, 0] == pytest.approx(50.0 / 150.0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_iou_range_and_symmetry(seed):
    rng = np.random.default_rng(seed)
    a, b = rand_boxes(rng, 7), rand_boxes(rng, 5)
    m = np.asarray(iou.iou_matrix(jnp.asarray(a), jnp.asarray(b)))
    mt = np.asarray(iou.iou_matrix(jnp.asarray(b), jnp.asarray(a)))
    assert np.all(m >= 0.0) and np.all(m <= 1.0 + 1e-12)
    np.testing.assert_allclose(m, mt.T, rtol=1e-12)
