"""L1 correctness: the Pallas Kalman kernels vs the pure-jnp oracle.

Hypothesis sweeps bank sizes, block sizes, dtypes and value ranges; every
case asserts allclose against ref.py (the CORE correctness signal for the
AOT path — the same graphs are what the Rust runtime executes).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kalman, ref

DIM_X = ref.DIM_X
DIM_Z = ref.DIM_Z


def make_state(rng, t, dtype=np.float64):
    """Random but physically-plausible tracker bank."""
    x = np.zeros((t, DIM_X), dtype=dtype)
    x[:, 0] = rng.uniform(0, 1920, t)      # u
    x[:, 1] = rng.uniform(0, 1080, t)      # v
    x[:, 2] = rng.uniform(10, 40000, t)    # s (area)
    x[:, 3] = rng.uniform(0.2, 5.0, t)     # r
    x[:, 4:] = rng.normal(0, 5, (t, 3))    # velocities
    a = rng.normal(0, 1, (t, DIM_X, DIM_X))
    p = np.matmul(a, np.swapaxes(a, -1, -2)) + 3.0 * np.eye(DIM_X)
    return x, p.astype(dtype)


def make_z(rng, t, dtype=np.float64):
    z = np.zeros((t, DIM_Z), dtype=dtype)
    z[:, 0] = rng.uniform(0, 1920, t)
    z[:, 1] = rng.uniform(0, 1080, t)
    z[:, 2] = rng.uniform(10, 40000, t)
    z[:, 3] = rng.uniform(0.2, 5.0, t)
    return z


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=33),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    mask_p=st.floats(min_value=0.0, max_value=1.0),
)
def test_predict_matches_ref(t, seed, mask_p):
    rng = np.random.default_rng(seed)
    x, p = make_state(rng, t)
    mask = (rng.uniform(0, 1, (t, 1)) < mask_p).astype(np.float64)
    xk, pk = kalman.predict(jnp.asarray(x), jnp.asarray(p), jnp.asarray(mask))
    xr, pr = ref.predict_ref(jnp.asarray(x), jnp.asarray(p), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=33),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    mask_p=st.floats(min_value=0.0, max_value=1.0),
)
def test_update_matches_ref(t, seed, mask_p):
    rng = np.random.default_rng(seed)
    x, p = make_state(rng, t)
    z = make_z(rng, t)
    zmask = (rng.uniform(0, 1, (t, 1)) < mask_p).astype(np.float64)
    xk, pk = kalman.update(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(z), jnp.asarray(zmask)
    )
    xr, pr = ref.update_ref(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(z), jnp.asarray(zmask)
    )
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), rtol=1e-9, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(
    bt=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_predict_block_size_invariance(bt, seed):
    """Result must not depend on the BlockSpec tile size."""
    t = 32
    if t % bt != 0:
        bt = 1
    rng = np.random.default_rng(seed)
    x, p = make_state(rng, t)
    mask = np.ones((t, 1))
    x1, p1 = kalman.predict(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(mask), block_t=bt
    )
    x2, p2 = kalman.predict(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(mask), block_t=t
    )
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-12)


def test_negative_area_guard():
    """SORT's guard: if x[6]+x[2] <= 0 the area velocity is zeroed."""
    rng = np.random.default_rng(0)
    x, p = make_state(rng, 4)
    x[1, 2] = 5.0
    x[1, 6] = -10.0     # would go negative
    x[2, 2] = 5.0
    x[2, 6] = -4.0      # stays positive
    mask = np.ones((4, 1))
    xk, _ = kalman.predict(jnp.asarray(x), jnp.asarray(p), jnp.asarray(mask))
    xk = np.asarray(xk)
    assert xk[1, 6] == 0.0                 # guard fired: ds <- 0
    assert xk[1, 2] == x[1, 2]             # area unchanged (ds was zeroed)
    assert xk[2, 2] == pytest.approx(x[2, 2] + x[2, 6])   # normal predict


def test_dead_slots_pass_through():
    rng = np.random.default_rng(1)
    x, p = make_state(rng, 8)
    mask = np.zeros((8, 1))
    xk, pk = kalman.predict(jnp.asarray(x), jnp.asarray(p), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(xk), x)
    np.testing.assert_array_equal(np.asarray(pk), p)
    z = make_z(rng, 8)
    xu, pu = kalman.update(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(z), jnp.asarray(mask)
    )
    np.testing.assert_array_equal(np.asarray(xu), x)
    np.testing.assert_array_equal(np.asarray(pu), p)


def test_update_covariance_symmetric_psd():
    """Joseph form must preserve symmetry and positive-definiteness."""
    rng = np.random.default_rng(2)
    x, p = make_state(rng, 6)
    z = make_z(rng, 6)
    mask = np.ones((6, 1))
    _, pk = kalman.update(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(z), jnp.asarray(mask)
    )
    pk = np.asarray(pk)
    np.testing.assert_allclose(pk, np.swapaxes(pk, -1, -2), rtol=1e-9, atol=1e-9)
    for i in range(6):
        evals = np.linalg.eigvalsh(pk[i])
        assert evals.min() > 0


def test_update_shrinks_uncertainty():
    """A measurement must not increase the observed-state variance."""
    rng = np.random.default_rng(3)
    x, p = make_state(rng, 5)
    z = make_z(rng, 5)
    mask = np.ones((5, 1))
    _, pk = kalman.update(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(z), jnp.asarray(mask)
    )
    pk = np.asarray(pk)
    for i in range(5):
        for j in range(DIM_Z):
            assert pk[i, j, j] <= p[i, j, j] + 1e-9


def test_f32_update_close():
    """The kernels also lower in f32 (edge deployments); looser tolerance."""
    rng = np.random.default_rng(4)
    x, p = make_state(rng, 8, dtype=np.float32)
    z = make_z(rng, 8, dtype=np.float32)
    mask = np.ones((8, 1), dtype=np.float32)
    xk, pk = kalman.update(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(z), jnp.asarray(mask)
    )
    xr, pr = ref.update_ref(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(z), jnp.asarray(mask)
    )
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), rtol=1e-3, atol=1e-2)


def test_sequential_filter_converges():
    """Track a constant-velocity object for 30 frames: the post-update
    position error must shrink well below the initial uncertainty."""
    t = 1
    bbox0 = np.array([100.0, 100.0, 150.0, 200.0])
    z0 = np.asarray(ref.bbox_to_z(jnp.asarray(bbox0)))
    x = np.concatenate([z0, np.zeros(3)])[None, :]
    p = np.asarray(ref.P0)[None, :, :]
    mask = np.ones((t, 1))
    err = None
    for k in range(1, 30):
        true_box = bbox0 + np.array([2.0 * k, 1.0 * k, 2.0 * k, 1.0 * k])
        z = np.asarray(ref.bbox_to_z(jnp.asarray(true_box)))[None, :]
        x, p = kalman.predict(jnp.asarray(x), jnp.asarray(p), jnp.asarray(mask))
        x, p = kalman.update(jnp.asarray(x), jnp.asarray(p), jnp.asarray(z), jnp.asarray(mask))
        x, p = np.asarray(x), np.asarray(p)
        err = abs(x[0, 0] - z[0, 0]) + abs(x[0, 1] - z[0, 1])
    assert err is not None and err < 0.5


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_inv4x4_spd_blockwise_matches_linalg(seed):
    """The kernel's 2x2-block Schur inverse vs jnp.linalg.inv on random
    SPD matrices (including poorly-scaled ones)."""
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, (6, 4, 4))
    scale = 10.0 ** rng.uniform(-2, 3, (6, 1, 1))
    s = (np.matmul(a, np.swapaxes(a, -1, -2)) + 2.0 * np.eye(4)) * scale
    got = np.asarray(kalman._inv4x4_spd(jnp.asarray(s)))
    want = np.linalg.inv(s)
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-10)
    # inverse property: S @ S^-1 = I
    prod = np.matmul(s, got)
    np.testing.assert_allclose(prod, np.tile(np.eye(4), (6, 1, 1)), rtol=1e-7, atol=1e-7)
