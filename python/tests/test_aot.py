"""AOT pipeline: artifacts get produced, parse as HLO text, and the
parity/golden exports carry what the Rust tests expect."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    arts = aot.lower_artifacts(str(d))
    aot.export_parity(str(d))
    aot.export_golden_tracks(str(d))
    with open(os.path.join(str(d), "manifest.json"), "w") as f:
        json.dump({"artifacts": arts}, f)
    return str(d)


def test_all_artifacts_exist(outdir):
    expected = ["bank_predict_iou.hlo.txt", "bank_update.hlo.txt"] + [
        f"bank_predict_T{t}.hlo.txt" for t in aot.PREDICT_SWEEP_T
    ]
    for name in expected:
        p = os.path.join(outdir, name)
        assert os.path.exists(p), name
        text = open(p).read()
        assert "ENTRY" in text and "HloModule" in text


def test_parity_json_structure(outdir):
    parity = json.load(open(os.path.join(outdir, "parity.json")))
    assert len(parity["constants"]["F"]) == 7
    assert len(parity["constants"]["H"]) == 4
    steps = parity["steps"]
    assert len(steps) >= 10
    s0 = steps[0]
    assert len(s0["x_pred"]) == 3 and len(s0["x_pred"][0]) == 7
    assert len(s0["p_post"][0]) == 7 and len(s0["p_post"][0][0]) == 7
    iou = parity["iou_case"]
    assert len(iou["iou"]) == len(iou["dets"])


def test_parity_constants_match_sort_spec(outdir):
    parity = json.load(open(os.path.join(outdir, "parity.json")))
    c = parity["constants"]
    assert c["Q"][6][6] == pytest.approx(0.0001)
    assert c["Q"][4][4] == pytest.approx(0.01)
    assert c["R"][2][2] == pytest.approx(10.0)
    assert c["P0"][0][0] == pytest.approx(10.0)
    assert c["P0"][4][4] == pytest.approx(10000.0)
    assert c["F"][0][4] == pytest.approx(1.0)


def test_golden_tracks_structure(outdir):
    g = json.load(open(os.path.join(outdir, "golden_tracks.json")))
    assert len(g["tracks"]) == len(g["frames"])
    # 3 objects tracked steadily after the min_hits warm-up
    final = g["tracks"][-1]
    assert len(final) == 3
    ids = sorted(int(t[4]) for t in final)
    assert ids == [1, 2, 3]


def test_hlo_has_expected_entry_shapes(outdir):
    text = open(os.path.join(outdir, "bank_update.hlo.txt")).read()
    t = model.BANK_T
    assert f"f64[{t},7]" in text
    assert f"f64[{t},7,7]" in text


def test_hlo_text_contains_full_constants(outdir):
    """Regression: as_hlo_text() must be called with
    print_large_constants=True — the default elides dense constants as
    `constant({...})`, which the Rust-side 0.5.1 text parser silently
    reconstructs as ZEROS (every artifact computed zeros while all
    Python tests passed)."""
    for name in ["bank_predict_T1.hlo.txt", "bank_update.hlo.txt", "bank_predict_iou.hlo.txt"]:
        text = open(os.path.join(outdir, name)).read()
        assert "constant({...})" not in text, f"{name}: elided constants"


def test_manifest_shapes_match_model(outdir):
    manifest = json.load(open(os.path.join(outdir, "manifest.json")))
    arts = manifest["artifacts"]
    assert arts["bank_update"]["inputs"][0][1] == [16, 7]
    assert arts["bank_predict_iou"]["outputs"][3][1] == [16, 16]
    for t in [1, 4, 16, 64, 256]:
        assert arts[f"bank_predict_T{t}"]["t"] == t
