"""Shared pytest config: enable x64 (the paper's doubles) and make the
`compile` and `baseline` packages importable regardless of invocation dir."""

import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
