"""Baseline SORT (the Table V comparator) behavioral tests.

These pin the *semantics* the Rust implementation must reproduce: the
golden_tracks.json parity file is only trustworthy if this baseline
behaves like abewley/sort.
"""

import numpy as np
import pytest

from baseline.sort_python import (
    KalmanBoxTracker,
    Sort,
    associate_detections_to_trackers,
    convert_bbox_to_z,
    convert_x_to_bbox,
    iou_batch,
)


@pytest.fixture(autouse=True)
def reset_id_counter():
    KalmanBoxTracker.count = 0
    yield


def moving_boxes(k, n=3):
    seeds = np.array(
        [[10.0, 20.0, 60.0, 140.0], [200.0, 50.0, 260.0, 170.0], [400.0, 300.0, 470.0, 420.0]]
    )[:n]
    vel = np.array([[3.0, 1.5], [-2.0, 0.5], [1.0, -2.0]])[:n]
    b = seeds.copy()
    b[:, [0, 2]] += vel[:, 0:1] * k
    b[:, [1, 3]] += vel[:, 1:2] * k
    return b


def dets_with_score(boxes):
    return np.hstack([boxes, np.ones((boxes.shape[0], 1))])


def test_bbox_roundtrip():
    b = np.array([10.0, 20.0, 60.0, 140.0])
    z = convert_bbox_to_z(b)
    x = np.vstack([z, np.zeros((3, 1))])
    back = convert_x_to_bbox(x)[0]
    np.testing.assert_allclose(back, b, rtol=1e-12)


def test_iou_batch_basics():
    a = np.array([[0.0, 0.0, 10.0, 10.0]])
    got = iou_batch(a, a)
    assert got[0, 0] == pytest.approx(1.0)


def test_association_prefers_best_iou():
    dets = np.array([[0.0, 0.0, 10.0, 10.0], [100.0, 100.0, 120.0, 120.0]])
    trks = np.array([[101.0, 101.0, 121.0, 121.0], [1.0, 1.0, 11.0, 11.0]])
    matched, ud, ut = associate_detections_to_trackers(dets, trks, 0.3)
    pairs = {tuple(m) for m in matched}
    assert pairs == {(0, 1), (1, 0)}
    assert len(ud) == 0 and len(ut) == 0


def test_association_low_iou_unmatched():
    dets = np.array([[0.0, 0.0, 10.0, 10.0]])
    trks = np.array([[50.0, 50.0, 60.0, 60.0]])
    matched, ud, ut = associate_detections_to_trackers(dets, trks, 0.3)
    assert matched.shape[0] == 0
    assert list(ud) == [0] and list(ut) == [0]


def test_sort_reports_after_min_hits():
    s = Sort(max_age=1, min_hits=3, iou_threshold=0.3)
    # frames 1..3 are within the min_hits grace period -> reported
    for k in range(3):
        tracks = s.update(dets_with_score(moving_boxes(k)))
        assert tracks.shape[0] == 3
    # steady state: still 3 tracks with stable ids
    ids = set(tracks[:, 4])
    tracks = s.update(dets_with_score(moving_boxes(3)))
    assert set(tracks[:, 4]) == ids


def test_sort_id_stability_over_long_run():
    s = Sort(max_age=1, min_hits=3, iou_threshold=0.3)
    ids_seen = set()
    for k in range(30):
        tracks = s.update(dets_with_score(moving_boxes(k)))
        ids_seen.update(tracks[:, 4].tolist())
    assert ids_seen == {1.0, 2.0, 3.0}   # no id churn on clean data


def test_sort_track_survives_single_dropout():
    """max_age=1: one missed frame keeps the tracker, two kill it."""
    s = Sort(max_age=1, min_hits=1, iou_threshold=0.3)
    for k in range(5):
        s.update(dets_with_score(moving_boxes(k)))
    n_before = len(s.trackers)
    s.update(np.empty((0, 5)))          # dropout frame
    assert len(s.trackers) == n_before  # still alive (coasting)
    tracks = s.update(dets_with_score(moving_boxes(6)))
    assert tracks.shape[0] == 3         # re-acquired, same trackers
    assert len({t.id for t in s.trackers}) == 3


def test_sort_track_dies_after_max_age():
    s = Sort(max_age=1, min_hits=1, iou_threshold=0.3)
    for k in range(5):
        s.update(dets_with_score(moving_boxes(k)))
    s.update(np.empty((0, 5)))
    s.update(np.empty((0, 5)))
    assert len(s.trackers) == 0


def test_sort_new_object_gets_new_id():
    s = Sort(max_age=1, min_hits=1, iou_threshold=0.3)
    for k in range(3):
        s.update(dets_with_score(moving_boxes(k, n=2)))
    # new object appears at frame 4; it is reported once it has a hit
    # streak (new trackers are born with hit_streak 0)
    boxes = np.vstack([moving_boxes(3), [[700.0, 700.0, 760.0, 800.0]]])
    s.update(dets_with_score(boxes))
    boxes = np.vstack([moving_boxes(4), [[700.0, 700.0, 760.0, 800.0]]])
    tracks = s.update(dets_with_score(boxes))
    assert tracks.shape[0] == 4
    assert tracks[:, 4].max() >= 3      # a fresh id was allocated


def test_sort_empty_input_returns_empty():
    s = Sort()
    out = s.update(np.empty((0, 5)))
    assert out.shape == (0, 5)
